"""Quickstart: train a reduced model for a few steps, then serve it.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-14b]

Every assigned architecture works (reduced configs run on CPU).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import configs                                    # noqa: E402
from repro.launch.serve import ServeRun, serve               # noqa: E402
from repro.launch.train import TrainRun, train               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    print(f"== training {args.arch} (reduced) for {args.steps} steps ==")
    hist = train(TrainRun(arch=args.arch, steps=args.steps, global_batch=8,
                          seq_len=32, lr=3e-3, log_every=5))
    first, last = hist["loss"][0][1], hist["loss"][-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({hist['steps_per_sec']:.2f} steps/s)")

    print(f"== serving {args.arch} (reduced): prefill + 16 tokens ==")
    serve(ServeRun(arch=args.arch, batch=2, prompt_len=16,
                   max_new_tokens=16))


if __name__ == "__main__":
    main()
