"""Tour of the FOS logical-hardware abstraction (paper Listings 1-5).

Shows the JSON descriptors for shells and accelerators, decoupled
compilation against a slot interface, relocation to a congruent slot,
slot merging for a bigger implementation alternative, and the generic
driver invoking a module purely from its descriptor.

    PYTHONPATH=src python examples/fos_registry_tour.py
"""
import json
import sys
import time

sys.path.insert(0, "src")

import numpy as np                                            # noqa: E402

from repro.core import Shell, default_registry, uniform_shell  # noqa: E402
from repro.core.module import AccelModule, run_placement       # noqa: E402


def main():
    reg = default_registry()

    print("== shell descriptor (paper Listing 1) ==")
    print(json.dumps(reg.shell("pod256_s4").to_json(), indent=2)[:400])

    print("\n== accelerator descriptor (paper Listing 2) ==")
    print(json.dumps(reg.module("mandelbrot").to_json(), indent=2))

    # single-device shell for the live part
    shell = Shell(uniform_shell("host1_s1", (1, 1), 1))
    desc = reg.module("mandelbrot")
    mod = AccelModule("mandelbrot", desc.load_builder(), desc.footprints)

    print("\n== decoupled compilation against the slot interface ==")
    t0 = time.perf_counter()
    pl = mod.place(shell.slots[0], 1)
    print(f"first compile: {(time.perf_counter() - t0) * 1e3:.1f} ms "
          f"(cache_hit={pl.cache_hit})")

    t0 = time.perf_counter()
    pl2 = mod.place(shell.slots[0], 1)
    print(f"relocation (congruent slot): "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms "
          f"(cache_hit={pl2.cache_hit})")

    print("\n== generic driver invocation (paper Listings 4/5) ==")
    rng = np.random.default_rng(0)
    re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
    im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
    out = run_placement(pl2, re, im)
    print(f"mandelbrot tile -> {np.asarray(out).shape}, "
          f"mean escape iter {float(np.asarray(out).mean()):.1f}")

    print("\n== module I/O signature (the ADR-map analogue) ==")
    prog = mod.program(shell.slots[0], 1)
    print(json.dumps(prog.signature(), indent=2)[:400])


if __name__ == "__main__":
    main()
