"""End-to-end driver: FOS multi-tenant acceleration service.

The paper's core scenario (section 5.5.2): mutually-unaware tenants submit
batched acceleration requests for *different* accelerators — an LM forward
(the "C accelerator"), mandelbrot (compute-bound) and sobel (memory-bound)
— and the resource-elastic daemon time/space-multiplexes them over the
shell's slots, replicating and reusing modules as load allows.

    PYTHONPATH=src python examples/multi_tenant_serving.py

Runs on the default 1-device view (single-slot shell -> pure
time-multiplexing).  Set XLA_FLAGS=--xla_force_host_platform_device_count=4
before running to watch spatial multiplexing over a 4-slot shell.
"""
import sys
import time

sys.path.insert(0, "src")

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core import Daemon, PolicyConfig, Shell, default_registry, \
    uniform_shell                                             # noqa: E402


def main():
    n_dev = jax.device_count()
    spec = uniform_shell(f"host{n_dev}_s{n_dev}", (1, n_dev), n_dev)
    reg = default_registry()
    # preemptive priority policy: carol's LM forward is latency-sensitive
    # (priority 3 + deadline); alice/bob run as best-effort batch work whose
    # chunks may be evicted and requeued to keep carol inside her SLO
    daemon = Daemon(Shell(spec), reg, PolicyConfig(preemptive=True))
    print(f"shell: {spec.name} ({n_dev} slots); modules: "
          f"{sorted(reg.modules)}")

    rng = np.random.default_rng(0)
    re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
    im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
    img = rng.random((1024, 1024)).astype(np.float32)
    toks = rng.integers(0, 256, (8, 64)).astype(np.int32)

    t0 = time.perf_counter()
    handles = {
        "alice/mandelbrot": daemon.submit("alice", "mandelbrot",
                                          [(re, im)] * 4),
        "bob/sobel": daemon.submit("bob", "sobel", [(img,)] * 4),
        "carol/lm-forward": daemon.submit("carol", "lm-forward",
                                          [(toks,)] * 2, priority=3,
                                          deadline_ms=5000.0),
    }
    for name, h in handles.items():
        outs = h.future.result(timeout=600)
        dt = time.perf_counter() - t0
        tag = f" (priority={h.priority})" if h.priority else ""
        print(f"  {name}: {len(outs)} chunks done at t={dt:.2f}s "
              f"(out[0] shape {np.asarray(outs[0]).shape}){tag}")
    s = daemon.stats
    print(f"stats: chunks={s['chunks']} reconfigurations="
          f"{s['reconfigurations']} reuses={s['reuses']} "
          f"preemptions={s['preemptions']} "
          f"scheduler={s['sched_ns'] / max(s['sched_calls'], 1) / 1e3:.0f}"
          f"us/event")
    daemon.shutdown()


if __name__ == "__main__":
    main()
