"""End-to-end driver: FOS multi-tenant acceleration over a fabric.

The paper's core scenario (section 5.5.2): mutually-unaware tenants submit
batched acceleration requests for *different* accelerators — an LM forward
(the "C accelerator"), mandelbrot (compute-bound) and sobel (memory-bound)
— and the resource-elastic policy time/space-multiplexes them over the
fabric's shells, replicating and reusing modules as load allows.

This is the Fabric-API port: shells are registered descriptors, the
fabric (a list of shell names) is itself a registered descriptor
(`fabrics.json`), and the daemon executes over all shells with
locality-aware placement and cross-shell work stealing — alice pins her
batch work to one shell with `affinity=`, and when the other shell goes
idle it steals her queued chunks.

Checkpointed preemption (`PolicyConfig.ckpt`): after the steady-state
tenants are admitted, dave fires a high-priority interactive *burst*
that evicts mid-flight batch chunks.  With checkpointing on, each
victim's progress is saved (priced by the cost model) instead of
discarded, and the chunk resumes at its remaining fraction — the
`ckpt` stats line shows the saves/restores/migrations the burst caused.

    PYTHONPATH=src python examples/multi_tenant_serving.py

Runs on the default 1-device view (single-shell fabric -> pure
time-multiplexing).  Set XLA_FLAGS=--xla_force_host_platform_device_count=4
before running to watch a two-shell fabric with spatial multiplexing and
stealing.
"""
import sys
import time

sys.path.insert(0, "src")

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core import Daemon, FabricDescriptor, ImplAlt, \
    ModuleDescriptor, PolicyConfig, QoSContract, Shell, \
    default_registry, uniform_shell                           # noqa: E402
from repro.obs import FlightRecorder, export_chrome_trace     # noqa: E402


def build_shells(reg):
    """Split the device view into a two-shell fabric when it is big
    enough; fall back to the degenerate one-shell fabric on 1 device."""
    devs = jax.devices()
    n_dev = len(devs)
    if n_dev >= 2:
        half = n_dev // 2
        spec_a = uniform_shell("shellA", (1, half), half)
        spec_b = uniform_shell("shellB", (1, n_dev - half), n_dev - half)
        shells = {"shellA": Shell(spec_a, devs[:half]),
                  "shellB": Shell(spec_b, devs[half:])}
    else:
        spec_a = uniform_shell("shellA", (1, 1), 1)
        shells = {"shellA": Shell(spec_a, devs)}
    for sh in shells.values():
        reg.register_shell(sh.spec)
    reg.register_fabric(FabricDescriptor("example", tuple(shells)))
    return shells


def main():
    reg = default_registry()
    shells = build_shells(reg)
    # preemptive priority policy with checkpointing: carol's LM forward
    # is latency-sensitive (priority 3 + deadline); alice/bob run as
    # best-effort batch work whose chunks may be evicted — keeping their
    # progress — requeued, resumed, or stolen by an idle shell
    # flight recorder (PR 9): full event tracing plus 100 ms gauge
    # sampling over the live daemon — the whole serving session below
    # lands in `daemon.metrics["obs"]` and a Perfetto-openable trace
    recorder = FlightRecorder(trace=True, sample_every_ms=100.0)
    daemon = Daemon(shells, reg,
                    PolicyConfig(preemptive=True, ckpt=True),
                    obs=recorder)
    fab = reg.fabric("example")
    print(f"fabric: {fab.name} -> "
          f"{[(n, len(s.slots)) for n, s in shells.items()]}; "
          f"modules: {sorted(reg.modules)}")

    rng = np.random.default_rng(0)
    re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
    im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
    img = rng.random((1024, 1024)).astype(np.float32)
    toks = rng.integers(0, 256, (8, 64)).astype(np.int32)

    first_shell = next(iter(shells))
    t0 = time.perf_counter()
    handles = {
        # alice pins her batch to one shell; the idle shell steals it
        "alice/mandelbrot": daemon.submit("alice", "mandelbrot",
                                          [(re, im)] * 4,
                                          affinity=first_shell),
        "bob/sobel": daemon.submit("bob", "sobel", [(img,)] * 4),
        "carol/lm-forward": daemon.submit("carol", "lm-forward",
                                          [(toks,)] * 2, priority=3,
                                          deadline_ms=5000.0),
    }
    # dave's interactive burst lands while the batch tenants are
    # mid-flight: high priority evicts resident chunks, whose progress
    # the checkpoint subsystem saves and later resumes
    time.sleep(0.2)
    frame = rng.random((1024, 1024)).astype(np.float32)
    for i in range(3):
        handles[f"dave/burst{i}"] = daemon.submit(
            "dave", "sobel", [(frame,)], priority=5, deadline_ms=2000.0)
    for name, h in handles.items():
        outs = h.future.result(timeout=600)
        dt = time.perf_counter() - t0
        tag = f" (priority={h.priority})" if h.priority else ""
        print(f"  {name}: {len(outs)} chunks done at t={dt:.2f}s "
              f"(out[0] shape {np.asarray(outs[0]).shape}){tag}")
    s = daemon.stats
    f = daemon.fabric.stats
    print(f"stats: chunks={s['chunks']} reconfigurations="
          f"{s['reconfigurations']} reuses={s['reuses']} "
          f"preemptions={s['preemptions']} "
          f"steals={f['steals']} stolen_chunks={f['stolen_chunks']} "
          f"local_dispatch={f['local_dispatch']} "
          f"scheduler={s['sched_ns'] / max(s['sched_calls'], 1) / 1e3:.0f}"
          f"us/event")
    c = daemon.ckpt_stats
    print(f"ckpt : saves={c.get('saves', 0)} "
          f"restores={c.get('restores', 0)} "
          f"migrations={c.get('migrations', 0)} "
          f"dropped={c.get('dropped', 0)}")

    # erin arrives late with a *QoS contract* (PR 7): 20 req/s at a
    # 35 ms p95 deadline, with "sobel-lite" (the same kernel declared
    # at a cheaper estimate) as her degraded tier.  Even on the now-
    # drained fabric the full sobel estimate is predicted infeasible at
    # that deadline, so the admission controller transparently DEGRADEs
    # her submit — the verdict and the per-tenant attainment ledger are
    # printed below.
    reg.register_module(ModuleDescriptor(
        name="sobel-lite", entrypoint="repro.core.zoo:build_sobel",
        impls=(ImplAlt("x1", 1, 2.0),), kind="fn"))
    daemon.register_contract(QoSContract(
        "erin", rate_per_s=20.0, deadline_ms=35.0,
        degraded="sobel-lite"))
    h_erin = daemon.submit("erin", "sobel", [(img,)], priority=4)
    v = daemon.fabric.jobs[h_erin.rid].verdict
    print(f"erin/sobel admission: {v.action}"
          + (f" -> {v.degraded_to!r} ({v.reason})"
             if v.action == "DEGRADE" else ""))
    h_erin.future.result(timeout=600)
    e = daemon.slo_stats.get("erin", {})
    att = e.get("attainment")
    print(f"slo  : erin submitted={e.get('submitted', 0)} "
          f"admitted={e.get('admitted', 0)} "
          f"degraded={e.get('degraded', 0)} "
          f"rejected={e.get('rejected', 0)} attainment="
          f"{att if att is None else format(att, '.2f')}")

    # the flight recorder saw the whole session: counters snapshot +
    # a chrome://tracing / Perfetto trace of every chunk span
    obs = daemon.metrics["obs"]
    oc = obs["counters"]
    print(f"obs  : submitted={oc['submitted']} "
          f"(admitted={oc['admitted']} degraded={oc['degraded']} "
          f"rejected={oc['rejected']}) "
          f"chunks={oc['chunks_started']}/{oc['chunks_completed']}"
          f"/{oc['chunks_preempted']} (start/done/evict) "
          f"steals={oc['steal_hits']}/{oc['steal_probes']} "
          f"samples={len(obs.get('samples', []))}")
    print(f"svc  : " + " ".join(
        f"{t}={ms:.0f}slot-ms"
        for t, ms in sorted(obs["tenant_service_ms"].items())))
    export_chrome_trace(recorder.tracer, "trace.json")
    print(f"trace: {len(recorder.tracer.events)} events -> trace.json "
          f"(open at https://ui.perfetto.dev)")
    daemon.shutdown()


if __name__ == "__main__":
    main()
