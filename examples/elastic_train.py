"""Elastic, fault-tolerant training end to end.

Demonstrates the FOS replacement primitive applied to a training job:
  1. train with async checkpointing;
  2. inject a fault mid-run -> supervisor restarts from the checkpoint;
  3. elastic re-partition mid-run (the scheduler re-allocating slots):
     save -> rebuild with different partitioning rules -> elastic restore.

    PYTHONPATH=src python examples/elastic_train.py [--steps 60] [--m100]

--m100 trains a ~100M-parameter llama-style config (slow on 1 CPU core;
the default is the reduced config so the demo finishes in seconds).
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch.train import TrainRun, train               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--m100", action="store_true",
                    help="~100M-param config (CPU-slow)")
    args = ap.parse_args()

    if args.m100:
        # ~100M params: register an ad-hoc config based on llama3.2-3b
        import dataclasses
        from repro import configs as cfgs
        from repro.models import api
        base = cfgs.get("llama3.2-3b")
        cfg = dataclasses.replace(
            base, name="llama-100m", n_layers=8, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000)
        print(f"~100M config: {api.param_count(cfg) / 1e6:.0f}M params")
        import repro.configs as _c
        import types
        mod = types.SimpleNamespace(CONFIG=cfg, REDUCED=cfg)
        _c._MODULES["llama-100m"] = "llama_100m"
        sys.modules["repro.configs.llama_100m"] = mod
        arch, reduced, batch, seq = "llama-100m", False, 4, 256
    else:
        arch, reduced, batch, seq = "llama3.2-3b", True, 8, 64

    with tempfile.TemporaryDirectory() as ckdir:
        hist = train(TrainRun(
            arch=arch, reduced=reduced, steps=args.steps,
            global_batch=batch, seq_len=seq, lr=3e-3,
            ckpt_dir=ckdir, ckpt_every=10,
            fail_at_step=args.steps // 3,          # injected fault
            elastic_switch_step=2 * args.steps // 3,  # re-partition
            log_every=10))
    print(f"done: steps={hist['final_step']} restarts={hist['restarts']} "
          f"elastic_switches={hist['elastic_switches']} "
          f"loss {hist['loss'][0][1]:.3f} -> {hist['loss'][-1][1]:.3f}")


if __name__ == "__main__":
    main()
