"""Compare dry-run artifact variants (baseline vs tagged runs) for the
EXPERIMENTS.md section-4 iteration log.

    python -m benchmarks.perf_compare qwen3-14b train_4k opt1 [opt2 ...]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from benchmarks.common import REPO

ART = REPO / "benchmarks" / "artifacts" / "dryrun" / "single"


def load(arch: str, shape: str, tag: str) -> dict:
    name = f"{shape}.json" if tag == "baseline" else f"{shape}__{tag}.json"
    return json.loads((ART / arch / name).read_text())


def describe(d: dict) -> dict:
    r = d.get("roofline", {})
    mem = d.get("full", {}).get("memory", {})
    per_dev = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)
               - mem.get("alias_size_in_bytes", 0))
    return {
        "compute_ms": r.get("compute_s", 0) * 1e3,
        "memory_ms": r.get("memory_s", 0) * 1e3,
        "collective_ms": r.get("collective_s", 0) * 1e3,
        "dominant": r.get("dominant"),
        "roofline_frac": r.get("roofline_fraction"),
        "useful": r.get("useful_flops_ratio"),
        "hbm_gib": per_dev / 1024 ** 3,
    }


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    tags = ["baseline"] + sys.argv[3:]
    rows = {t: describe(load(arch, shape, t)) for t in tags}
    keys = ["compute_ms", "memory_ms", "collective_ms", "dominant",
            "roofline_frac", "useful", "hbm_gib"]
    print(f"{'metric':<16}" + "".join(f"{t:>16}" for t in tags))
    for k in keys:
        vals = []
        for t in tags:
            v = rows[t][k]
            vals.append(f"{v:>16.3f}" if isinstance(v, float)
                        else f"{str(v):>16}")
        print(f"{k:<16}" + "".join(vals))
    # top per-op deltas if available
    for t in tags[1:]:
        b_ops = load(arch, shape, "baseline").get(
            "extrapolated", {}).get("g2", {}).get("by_op")
        t_ops = load(arch, shape, t).get(
            "extrapolated", {}).get("g2", {}).get("by_op")
        if b_ops and t_ops:
            print(f"\n-- per-op g2 bytes: baseline -> {t} (GiB)")
            ops = sorted(set(b_ops) | set(t_ops),
                         key=lambda o: -(b_ops.get(o, {}).get("bytes", 0)))
            for o in ops[:10]:
                b = b_ops.get(o, {}).get("bytes", 0) / 1024 ** 3
                n = t_ops.get(o, {}).get("bytes", 0) / 1024 ** 3
                print(f"  {o:<22} {b:>9.2f} -> {n:>9.2f}")


if __name__ == "__main__":
    main()
