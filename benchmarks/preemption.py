"""THEMIS-style fairness-vs-throughput sweep: preemptive vs cooperative.

Two batch tenants (priority 0) keep a 4-slot shell saturated with
long-chunk requests while an interactive tenant (priority 3, 25 ms
deadline) submits short requests at increasing rates.  Each load point
replays the identical trace under the cooperative run-to-completion
policy and the preemptive policy and reports:

  - high-priority p95 latency (the headline THEMIS metric),
  - deadline-miss rate of the interactive class,
  - aggregate slot occupancy and goodput (occupancy minus work that a
    later eviction discarded),
  - preemption count,
  - Jain's fairness index over per-tenant mean latency.

Expected shape: preemption cuts high-priority p95 by the length of a
batch chunk at equal-or-better occupancy, at the cost of a few percent
of discarded work at the highest interactive rates.
"""
from __future__ import annotations

import random
import sys

from benchmarks.common import row
from repro.core import ImplAlt, ModuleDescriptor, PolicyConfig, Registry, \
    SimJob, simulate

SLOTS = 4
PRIORITY_HI = 3
DEADLINE_MS = 25.0
HORIZON_MS = 2000.0
# slow aging: background batch work may close a one-level gap per 300 ms
# waited, so the interactive class keeps its edge at sane backlogs while
# batch tenants still cannot starve
STARVATION_BOUND_MS = 300.0


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 48.0), ImplAlt("x2", 2, 26.0),
               ImplAlt("x4", 4, 14.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 5.0), ImplAlt("x2", 2, 3.0))))
    return reg


def trace(inter_period_ms: float, rng: random.Random,
          horizon_ms: float = HORIZON_MS) -> list[SimJob]:
    """Batch background load + Poisson-ish interactive arrivals."""
    jobs = []
    for tenant in ("batch0", "batch1"):
        t = 0.0
        while t < horizon_ms:
            jobs.append(SimJob(t, tenant, "batch",
                               rng.randint(3, 6)))
            t += rng.uniform(80.0, 220.0)
    t = rng.uniform(0.0, inter_period_ms)
    while t < horizon_ms:
        jobs.append(SimJob(t, "live", "inter", 1, priority=PRIORITY_HI,
                           deadline_ms=DEADLINE_MS))
        t += rng.expovariate(1.0 / inter_period_ms)
    return jobs


def jain(xs: list[float]) -> float:
    if not xs:
        return 1.0
    return (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs))


def main(quick: bool = False) -> list[str]:
    """`quick` shrinks the trace for the CI benchmarks-smoke job."""
    reg = _registry()
    horizon = 400.0 if quick else HORIZON_MS
    periods = (40.0,) if quick else (40.0, 20.0, 10.0)
    rows = []
    for period in periods:
        jobs = trace(period, random.Random(0), horizon_ms=horizon)
        res = {}
        policies = (
            ("coop", PolicyConfig(preemptive=False,
                                   starvation_bound_ms=STARVATION_BOUND_MS)),
            ("preempt", PolicyConfig(preemptive=True,
                                     starvation_bound_ms=STARVATION_BOUND_MS)))
        for name, pol in policies:
            r = simulate(reg, SLOTS, jobs, pol)
            res[name] = r
            tenants = sorted({m["tenant"] for m in r.request_meta.values()})
            per_tenant = []
            for t in tenants:
                lats = [r.request_latency[rid]
                        for rid, m in r.request_meta.items()
                        if m["tenant"] == t]
                per_tenant.append(sum(lats) / len(lats))
            rows.append(row(
                f"themis/ia{period:g}/{name}/hi_p95",
                r.p95_latency(priority=PRIORITY_HI) * 1e3,
                f"miss_rate={r.deadline_miss_rate:.3f} "
                f"util={r.utilization:.3f} "
                f"goodput={r.useful_utilization:.3f} "
                f"preemptions={r.preemptions} "
                f"jain={jain(per_tenant):.3f}"))
        speedup = (res["coop"].p95_latency(priority=PRIORITY_HI)
                   / max(res["preempt"].p95_latency(priority=PRIORITY_HI), 1e-9))
        util_delta = (res["preempt"].utilization
                      - res["coop"].utilization)
        # occupancy counts evicted partial work as busy; goodput is the
        # honest efficiency number (it excludes discarded work)
        goodput_delta = (res["preempt"].useful_utilization
                         - res["coop"].useful_utilization)
        rows.append(row(
            f"themis/ia{period:g}/preempt_vs_coop", 0.0,
            f"hi_p95_speedup={speedup:.2f}x "
            f"util_delta={util_delta:+.3f} "
            f"goodput_delta={goodput_delta:+.3f} "
            f"miss_delta={res['preempt'].deadline_miss_rate - res['coop'].deadline_miss_rate:+.3f}"))
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv[1:])
