"""THEMIS-style fairness-vs-throughput sweep: preemption, reservation,
checkpointing.

Two batch tenants (priority 0) keep a 4-slot shell saturated with
long-chunk requests while an interactive tenant (priority 3, 25 ms
deadline) submits short requests at increasing rates.  Each load point
replays the identical trace under four policies:

  - **coop**: cooperative run-to-completion (the lossless baseline);
  - **reserve**: cooperative + `reserve_slots=1` — the last slot is held
    back for the interactive class (steal-aware admission: capacity is
    found, not evicted — the cheap alternative to checkpointing);
  - **preempt**: chunk-granularity eviction, evicted partial work
    discarded;
  - **preempt+ckpt**: eviction with context save/restore
    (`PolicyConfig.ckpt`) — evicted chunks keep their progress and
    resume at the remaining fraction, at the priced save/restore cost.

Reported per policy: high-priority p95 latency (the headline THEMIS
metric), deadline-miss rate, occupancy, goodput (occupancy minus
discarded work), preemption count, Jain's fairness index, and the
discarded/reclaimed slot-time split (`SimResult.discarded_ms` /
`reclaimed_ms`).

Expected shape: preemption cuts high-priority p95 by the length of a
batch chunk at equal-or-better occupancy but discards up to ~26% of
slot-time at the 10 ms interactive rate; checkpointing reclaims most of
that at the same p95 (the save hides under the preemptor's
reconfiguration); reservation gets the p95 win without any eviction, at
the price of the held-back slot's idle capacity.

`--ckpt` enforces the acceptance gate (CI): at the finest interactive
rate, checkpointing must reclaim >= 50% of the slot-time the plain
preemptive policy discards, at equal-or-better high-priority p95.

**Predictive reservation** (`reserve_mode="adaptive"`, core/arrivals.py)
gets its own section on a *drifting*-rate trace: the interactive
inter-arrival drifts 10 ms -> 80 ms -> 10 ms within one run, so any
static `reserve_slots` setting is wrong on at least one phase — too
small when the burst is hot (interactive queues behind batch chunks),
too large when it cools (reserved capacity idles and batch throughput
collapses).  The adaptive policy sizes the reservation online from the
observed arrival rate and is compared per phase against every static
setting; the first `SETTLE_MS` of each phase are excluded from the
per-phase p95 for *all* policies alike (reservation drain + estimator
adaptation are inside that window by design).

`--adaptive` enforces the acceptance gate (CI): on every phase the
adaptive p95 must stay within `ADAPT_ENVELOPE`x of the per-phase-best
static (plus one reconfiguration penalty of absolute slack — at
single-digit-millisecond latencies one reconfig is measurement
granularity), while every static setting must lose somewhere — either
break that envelope on some phase (and then adaptive must beat it >=
`ADAPT_ENVELOPE`x on its worst phase) or fall short of the adaptive
policy's goodput; any static that matches the latency envelope
everywhere must trail adaptive goodput by at least `GOODPUT_MARGIN`.
"""
from __future__ import annotations

import argparse
import random
import sys

from benchmarks.common import row, write_bench
from repro.core import ImplAlt, ModuleDescriptor, PolicyConfig, Registry, \
    SimJob, simulate
from repro.core.simulator import p95

SLOTS = 4
PRIORITY_HI = 3
DEADLINE_MS = 25.0
HORIZON_MS = 2000.0
# slow aging: background batch work may close a one-level gap per 300 ms
# waited, so the interactive class keeps its edge at sane backlogs while
# batch tenants still cannot starve
STARVATION_BOUND_MS = 300.0
# CI gate: well below the expected ~80-90% reclaim at ia=10 (same style
# as the 1.3x hetero bound)
RECLAIM_GATE = 0.5

# -- drifting-rate trace (predictive reservation) ------------------------
# interactive inter-arrival per phase: hot burst -> cool-down -> hot
# burst again, so no static reserve_slots value fits the whole trace
DRIFT_PHASES = ((10.0, 1300.0), (80.0, 2600.0), (10.0, 1300.0))
STATIC_RESERVES = (0, 1, 2)
RESERVE_MAX = 2
# per-phase warm-up excluded from the p95 of *every* policy: covers the
# estimator's adaptation plus the drain of a resident batch chunk out
# of a newly reserved slot
SETTLE_MS = 250.0
ADAPT_ENVELOPE = 1.2
GOODPUT_MARGIN = 0.05


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 48.0), ImplAlt("x2", 2, 26.0),
               ImplAlt("x4", 4, 14.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 5.0), ImplAlt("x2", 2, 3.0))))
    return reg


def trace(inter_period_ms: float, rng: random.Random,
          horizon_ms: float = HORIZON_MS) -> list[SimJob]:
    """Batch background load + Poisson-ish interactive arrivals."""
    jobs = []
    for tenant in ("batch0", "batch1"):
        t = 0.0
        while t < horizon_ms:
            jobs.append(SimJob(t, tenant, "batch",
                               rng.randint(3, 6)))
            t += rng.uniform(80.0, 220.0)
    t = rng.uniform(0.0, inter_period_ms)
    while t < horizon_ms:
        jobs.append(SimJob(t, "live", "inter", 1, priority=PRIORITY_HI,
                           deadline_ms=DEADLINE_MS))
        t += rng.expovariate(1.0 / inter_period_ms)
    return jobs


def jain(xs: list[float]) -> float:
    if not xs:
        return 1.0
    return (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs))


def drifting_trace(rng: random.Random,
                   phases=DRIFT_PHASES) -> tuple[list[SimJob], list]:
    """Batch background over the whole horizon + interactive arrivals
    whose inter-arrival drifts per phase; returns (jobs, phase bounds)."""
    horizon = sum(length for _, length in phases)
    jobs = []
    for tenant in ("batch0", "batch1"):
        t = 0.0
        while t < horizon:
            jobs.append(SimJob(t, tenant, "batch", rng.randint(3, 6)))
            t += rng.uniform(80.0, 220.0)
    t0, bounds = 0.0, []
    for ia, length in phases:
        t = t0 + rng.uniform(0.0, ia)
        while t < t0 + length:
            jobs.append(SimJob(t, "live", "inter", 1,
                               priority=PRIORITY_HI,
                               deadline_ms=DEADLINE_MS))
            t += rng.expovariate(1.0 / ia)
        bounds.append((t0, t0 + length))
        t0 += length
    return jobs, bounds


def _phase_p95s(res, bounds, settle: float = SETTLE_MS) -> list[float]:
    """Hi-prio p95 per phase, excluding each phase's settle window."""
    out = []
    for a, b in bounds:
        out.append(p95([
            lat for rid, lat in res.request_latency.items()
            if res.request_meta[rid]["priority"] == PRIORITY_HI
            and a + settle <= res.request_meta[rid]["t_submit"] < b]))
    return out


def _mean_reserve(res, bounds) -> list[float]:
    """Time-weighted mean effective reservation per phase (shell0)."""
    hist = list(res.reserve_history.get("shell0", []))
    out = []
    for a, b in bounds:
        level, t_prev, acc = 0, a, 0.0
        for t, n in hist:
            if t >= b:
                break
            if t <= a:
                level = n
                continue
            acc += level * (t - t_prev)
            level, t_prev = n, t
        acc += level * (b - t_prev)
        out.append(acc / (b - a))
    return out


def adaptive_section(gate: bool = False) -> tuple[list[str], dict]:
    """Predictive-reservation rows on the drifting-rate trace; with
    `gate`, enforce the acceptance bounds (exits non-zero on failure).
    Returns (csv rows, metrics for the BENCH artifact).  Runs at full
    size even under --quick: one simulation is ~0.1 s and the per-phase
    p95s need their sample counts."""
    reg = _registry()
    jobs, bounds = drifting_trace(random.Random(2))
    kw = {"starvation_bound_ms": STARVATION_BOUND_MS,
          "preemptive": False}
    policies = [(f"static{n}", PolicyConfig(reserve_slots=n, **kw))
                for n in STATIC_RESERVES]
    policies.append(("adaptive", PolicyConfig(
        reserve_mode="adaptive", reserve_slots_max=RESERVE_MAX, **kw)))
    rows, res, phases = [], {}, {}
    for name, pol in policies:
        r = simulate(reg, SLOTS, jobs, pol)
        res[name] = r
        phases[name] = _phase_p95s(r, bounds)
        extra = ""
        if name == "adaptive":
            mean = _mean_reserve(r, bounds)
            extra = (" mean_reserve=" +
                     "/".join(f"{m:.2f}" for m in mean) +
                     f" resizes={len(r.reserve_history['shell0'])}")
        rows.append(row(
            f"themis/drift/{name}/hi_p95_phases", 0.0,
            "p95_ms=" + "/".join(f"{p * 1.0:.1f}" for p in phases[name])
            + f" goodput={r.useful_utilization:.3f} "
            f"miss_rate={r.deadline_miss_rate:.3f} "
            f"makespan={r.makespan:.0f}ms" + extra))
    # per-phase envelope: adaptive must track the best static on every
    # phase; one reconfiguration penalty of absolute slack on top of
    # the multiplicative bound (see module docstring)
    pen = policies[0][1].reconfig_penalty_ms
    best = [min(phases[f"static{n}"][i] for n in STATIC_RESERVES)
            for i in range(len(bounds))]
    allowed = [max(ADAPT_ENVELOPE * b, b + pen) for b in best]
    adapt = phases["adaptive"]
    g_adapt = res["adaptive"].useful_utilization

    def loses(name: str) -> str | None:
        """How a static setting loses to adaptive (None = it doesn't)."""
        bad = [i for i in range(len(bounds))
               if phases[name][i] > allowed[i] + 1e-9]
        if bad:
            worst = max(bad, key=lambda i: phases[name][i] / max(
                adapt[i], 1e-9))
            ratio = phases[name][worst] / max(adapt[worst], 1e-9)
            if gate and ratio < ADAPT_ENVELOPE:
                print(f"FAIL: adaptive only {ratio:.2f}x better than "
                      f"{name} on its mismatched phase {worst} "
                      f"(acceptance: >={ADAPT_ENVELOPE}x)",
                      file=sys.stderr)
                sys.exit(1)
            return (f"p95 phase{worst} "
                    f"{phases[name][worst]:.1f}ms vs adaptive "
                    f"{adapt[worst]:.1f}ms ({ratio:.1f}x)")
        if res[name].useful_utilization < g_adapt - GOODPUT_MARGIN:
            return (f"goodput {res[name].useful_utilization:.3f} vs "
                    f"adaptive {g_adapt:.3f}")
        return None

    summary = []
    for n in STATIC_RESERVES:
        how = loses(f"static{n}")
        summary.append(f"static{n}: " + (how or "does NOT lose"))
        if gate and how is None:
            print(f"FAIL: static{n} matches adaptive on every phase at "
                  f"equal goodput — the drifting trace no longer "
                  f"separates them", file=sys.stderr)
            sys.exit(1)
    for i in range(len(bounds)):
        if gate and adapt[i] > allowed[i] + 1e-9:
            print(f"FAIL: adaptive hi-prio p95 {adapt[i]:.2f}ms on "
                  f"phase {i} exceeds the {ADAPT_ENVELOPE}x envelope "
                  f"of the per-phase-best static "
                  f"({best[i]:.2f}ms, allowed {allowed[i]:.2f}ms)",
                  file=sys.stderr)
            sys.exit(1)
    rows.append(row("themis/drift/adaptive_vs_static", 0.0,
                    "; ".join(summary)))
    metrics = {name: {"p95_phases_ms": [round(p, 3) for p in ps],
                      "goodput": round(res[name].useful_utilization, 4)}
               for name, ps in phases.items()}
    metrics["static_losses"] = summary
    return rows, metrics


def _policies() -> list[tuple[str, PolicyConfig]]:
    kw = {"starvation_bound_ms": STARVATION_BOUND_MS}
    return [
        ("coop", PolicyConfig(preemptive=False, **kw)),
        ("reserve", PolicyConfig(preemptive=False, reserve_slots=1,
                                 reserve_priority=1, **kw)),
        ("preempt", PolicyConfig(preemptive=True, **kw)),
        ("preempt+ckpt", PolicyConfig(preemptive=True, ckpt=True, **kw)),
    ]


def main(quick: bool = False, ckpt_gate: bool = False,
         adaptive_gate: bool = False, out: str = "") -> list[str]:
    """`quick` shrinks the rate sweep for the CI benchmarks-smoke job
    (the drifting-rate section always runs full size — it is cheap and
    its per-phase p95s need their sample counts); `ckpt_gate` enforces
    the >= 50% reclaim acceptance bound at the finest interactive rate;
    `adaptive_gate` enforces the predictive-reservation bounds on the
    drifting trace (either gate exits non-zero on failure); `out` names
    the BENCH_4.json artifact ('' disables, the programmatic default —
    benchmarks/run.py must not drop artifacts in the caller's cwd)."""
    reg = _registry()
    horizon = 400.0 if quick else HORIZON_MS
    periods = (40.0,) if quick else (40.0, 20.0, 10.0)
    if ckpt_gate and 10.0 not in periods:
        periods = periods + (10.0,)     # the gate needs the hot point
    rows = []
    metrics: dict = {"trace": {"slots": SLOTS, "horizon_ms": horizon,
                               "periods_ms": list(periods),
                               "quick": quick}}
    gate_reclaim = gate_p95 = None
    for period in periods:
        jobs = trace(period, random.Random(0), horizon_ms=horizon)
        res = {}
        for name, pol in _policies():
            r = simulate(reg, SLOTS, jobs, pol)
            res[name] = r
            tenants = sorted({m["tenant"] for m in r.request_meta.values()})
            per_tenant = []
            for t in tenants:
                lats = [r.request_latency[rid]
                        for rid, m in r.request_meta.items()
                        if m["tenant"] == t]
                per_tenant.append(sum(lats) / len(lats))
            rows.append(row(
                f"themis/ia{period:g}/{name}/hi_p95",
                r.p95_latency(priority=PRIORITY_HI) * 1e3,
                f"miss_rate={r.deadline_miss_rate:.3f} "
                f"util={r.utilization:.3f} "
                f"goodput={r.useful_utilization:.3f} "
                f"preemptions={r.preemptions} "
                f"discarded={r.discarded_ms:.0f}ms "
                f"reclaimed={r.reclaimed_ms:.0f}ms "
                f"jain={jain(per_tenant):.3f}"))
        speedup = (res["coop"].p95_latency(priority=PRIORITY_HI)
                   / max(res["preempt"].p95_latency(priority=PRIORITY_HI), 1e-9))
        util_delta = (res["preempt"].utilization
                      - res["coop"].utilization)
        # occupancy counts evicted partial work as busy; goodput is the
        # honest efficiency number (it excludes discarded work)
        goodput_delta = (res["preempt"].useful_utilization
                         - res["coop"].useful_utilization)
        rows.append(row(
            f"themis/ia{period:g}/preempt_vs_coop", 0.0,
            f"hi_p95_speedup={speedup:.2f}x "
            f"util_delta={util_delta:+.3f} "
            f"goodput_delta={goodput_delta:+.3f} "
            f"miss_delta={res['preempt'].deadline_miss_rate - res['coop'].deadline_miss_rate:+.3f}"))
        # checkpointing vs plain preemption: how much of the previously
        # discarded slot-time the context saves bring back, at what p95
        d_pre = res["preempt"].discarded_ms
        d_ck = res["preempt+ckpt"].discarded_ms
        # nothing discarded -> nothing to reclaim: vacuously perfect
        # (the gate must not fail a trace with zero evicted work)
        reclaim_frac = 1.0 - d_ck / d_pre if d_pre > 0 else 1.0
        p95_pre = res["preempt"].p95_latency(priority=PRIORITY_HI)
        p95_ck = res["preempt+ckpt"].p95_latency(priority=PRIORITY_HI)
        rows.append(row(
            f"themis/ia{period:g}/ckpt_vs_preempt", 0.0,
            f"reclaim_frac={reclaim_frac:.2f} "
            f"(discarded {d_pre:.0f}->{d_ck:.0f}ms) "
            f"saves={res['preempt+ckpt'].ckpt_saves} "
            f"restores={res['preempt+ckpt'].ckpt_restores} "
            f"hi_p95={p95_pre:.1f}->{p95_ck:.1f}ms "
            f"goodput_delta="
            f"{res['preempt+ckpt'].useful_utilization - res['preempt'].useful_utilization:+.3f}"))
        rows.append(row(
            f"themis/ia{period:g}/reserve_vs_coop", 0.0,
            f"hi_p95={res['coop'].p95_latency(priority=PRIORITY_HI):.1f}"
            f"->{res['reserve'].p95_latency(priority=PRIORITY_HI):.1f}ms "
            f"util_delta="
            f"{res['reserve'].utilization - res['coop'].utilization:+.3f} "
            f"preemptions={res['reserve'].preemptions}"))
        metrics[f"ia{period:g}"] = {
            "hi_p95_ms": {n: round(
                r.p95_latency(priority=PRIORITY_HI), 3)
                for n, r in res.items()},
            "goodput": {n: round(r.useful_utilization, 4)
                        for n, r in res.items()},
            "preempt_p95_speedup": round(speedup, 3),
            "reclaim_frac": round(reclaim_frac, 4),
            "discarded_ms": {"preempt": round(d_pre, 1),
                             "preempt+ckpt": round(d_ck, 1)},
        }
        if period == 10.0:
            gate_reclaim, gate_p95 = reclaim_frac, (p95_pre, p95_ck)
        if ckpt_gate and period == 10.0:
            if reclaim_frac < RECLAIM_GATE:
                print(f"FAIL: checkpointing reclaimed only "
                      f"{reclaim_frac:.2f} of discarded slot-time "
                      f"(acceptance: >={RECLAIM_GATE})", file=sys.stderr)
                sys.exit(1)
            if p95_ck > p95_pre + 1e-9:
                print(f"FAIL: checkpointing regressed hi-prio p95 "
                      f"({p95_pre:.2f} -> {p95_ck:.2f} ms)",
                      file=sys.stderr)
                sys.exit(1)
    drift_rows, drift_metrics = adaptive_section(gate=adaptive_gate)
    rows.extend(drift_rows)
    metrics["drift"] = drift_metrics
    # only reached with every enforced gate satisfied (failures exited
    # above), so the artifact records which bounds were actually held
    write_bench(out, 4, "preemption", metrics, gates={
        "reclaim_min": RECLAIM_GATE,
        "reclaim_frac_ia10": (round(gate_reclaim, 4)
                              if gate_reclaim is not None else None),
        "ckpt_p95_ia10_ms": ([round(p, 3) for p in gate_p95]
                             if gate_p95 is not None else None),
        "adapt_envelope": ADAPT_ENVELOPE,
        "goodput_margin": GOODPUT_MARGIN,
        "enforced": {"ckpt": ckpt_gate, "adaptive": adaptive_gate},
        "pass": True,
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="shrink the rate sweep for CI smoke")
    ap.add_argument("--ckpt", action="store_true",
                    help="enforce the checkpoint reclaim gate")
    ap.add_argument("--adaptive", action="store_true",
                    help="enforce the predictive-reservation gate")
    ap.add_argument("--out", default="BENCH_4.json",
                    help="result JSON path ('' disables)")
    args = ap.parse_args()
    main(quick=args.quick, ckpt_gate=args.ckpt,
         adaptive_gate=args.adaptive, out=args.out)
