"""THEMIS-style fairness-vs-throughput sweep: preemption, reservation,
checkpointing.

Two batch tenants (priority 0) keep a 4-slot shell saturated with
long-chunk requests while an interactive tenant (priority 3, 25 ms
deadline) submits short requests at increasing rates.  Each load point
replays the identical trace under four policies:

  - **coop**: cooperative run-to-completion (the lossless baseline);
  - **reserve**: cooperative + `reserve_slots=1` — the last slot is held
    back for the interactive class (steal-aware admission: capacity is
    found, not evicted — the cheap alternative to checkpointing);
  - **preempt**: chunk-granularity eviction, evicted partial work
    discarded;
  - **preempt+ckpt**: eviction with context save/restore
    (`PolicyConfig.ckpt`) — evicted chunks keep their progress and
    resume at the remaining fraction, at the priced save/restore cost.

Reported per policy: high-priority p95 latency (the headline THEMIS
metric), deadline-miss rate, occupancy, goodput (occupancy minus
discarded work), preemption count, Jain's fairness index, and the
discarded/reclaimed slot-time split (`SimResult.discarded_ms` /
`reclaimed_ms`).

Expected shape: preemption cuts high-priority p95 by the length of a
batch chunk at equal-or-better occupancy but discards up to ~26% of
slot-time at the 10 ms interactive rate; checkpointing reclaims most of
that at the same p95 (the save hides under the preemptor's
reconfiguration); reservation gets the p95 win without any eviction, at
the price of the held-back slot's idle capacity.

`--ckpt` enforces the acceptance gate (CI): at the finest interactive
rate, checkpointing must reclaim >= 50% of the slot-time the plain
preemptive policy discards, at equal-or-better high-priority p95.
"""
from __future__ import annotations

import random
import sys

from benchmarks.common import row
from repro.core import ImplAlt, ModuleDescriptor, PolicyConfig, Registry, \
    SimJob, simulate

SLOTS = 4
PRIORITY_HI = 3
DEADLINE_MS = 25.0
HORIZON_MS = 2000.0
# slow aging: background batch work may close a one-level gap per 300 ms
# waited, so the interactive class keeps its edge at sane backlogs while
# batch tenants still cannot starve
STARVATION_BOUND_MS = 300.0
# CI gate: well below the expected ~80-90% reclaim at ia=10 (same style
# as the 1.3x hetero bound)
RECLAIM_GATE = 0.5


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 48.0), ImplAlt("x2", 2, 26.0),
               ImplAlt("x4", 4, 14.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 5.0), ImplAlt("x2", 2, 3.0))))
    return reg


def trace(inter_period_ms: float, rng: random.Random,
          horizon_ms: float = HORIZON_MS) -> list[SimJob]:
    """Batch background load + Poisson-ish interactive arrivals."""
    jobs = []
    for tenant in ("batch0", "batch1"):
        t = 0.0
        while t < horizon_ms:
            jobs.append(SimJob(t, tenant, "batch",
                               rng.randint(3, 6)))
            t += rng.uniform(80.0, 220.0)
    t = rng.uniform(0.0, inter_period_ms)
    while t < horizon_ms:
        jobs.append(SimJob(t, "live", "inter", 1, priority=PRIORITY_HI,
                           deadline_ms=DEADLINE_MS))
        t += rng.expovariate(1.0 / inter_period_ms)
    return jobs


def jain(xs: list[float]) -> float:
    if not xs:
        return 1.0
    return (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs))


def _policies() -> list[tuple[str, PolicyConfig]]:
    kw = {"starvation_bound_ms": STARVATION_BOUND_MS}
    return [
        ("coop", PolicyConfig(preemptive=False, **kw)),
        ("reserve", PolicyConfig(preemptive=False, reserve_slots=1,
                                 reserve_priority=1, **kw)),
        ("preempt", PolicyConfig(preemptive=True, **kw)),
        ("preempt+ckpt", PolicyConfig(preemptive=True, ckpt=True, **kw)),
    ]


def main(quick: bool = False, ckpt_gate: bool = False) -> list[str]:
    """`quick` shrinks the trace for the CI benchmarks-smoke job;
    `ckpt_gate` enforces the >= 50% reclaim acceptance bound at the
    finest interactive rate (exits non-zero below it)."""
    reg = _registry()
    horizon = 400.0 if quick else HORIZON_MS
    periods = (40.0,) if quick else (40.0, 20.0, 10.0)
    if ckpt_gate and 10.0 not in periods:
        periods = periods + (10.0,)     # the gate needs the hot point
    rows = []
    for period in periods:
        jobs = trace(period, random.Random(0), horizon_ms=horizon)
        res = {}
        for name, pol in _policies():
            r = simulate(reg, SLOTS, jobs, pol)
            res[name] = r
            tenants = sorted({m["tenant"] for m in r.request_meta.values()})
            per_tenant = []
            for t in tenants:
                lats = [r.request_latency[rid]
                        for rid, m in r.request_meta.items()
                        if m["tenant"] == t]
                per_tenant.append(sum(lats) / len(lats))
            rows.append(row(
                f"themis/ia{period:g}/{name}/hi_p95",
                r.p95_latency(priority=PRIORITY_HI) * 1e3,
                f"miss_rate={r.deadline_miss_rate:.3f} "
                f"util={r.utilization:.3f} "
                f"goodput={r.useful_utilization:.3f} "
                f"preemptions={r.preemptions} "
                f"discarded={r.discarded_ms:.0f}ms "
                f"reclaimed={r.reclaimed_ms:.0f}ms "
                f"jain={jain(per_tenant):.3f}"))
        speedup = (res["coop"].p95_latency(priority=PRIORITY_HI)
                   / max(res["preempt"].p95_latency(priority=PRIORITY_HI), 1e-9))
        util_delta = (res["preempt"].utilization
                      - res["coop"].utilization)
        # occupancy counts evicted partial work as busy; goodput is the
        # honest efficiency number (it excludes discarded work)
        goodput_delta = (res["preempt"].useful_utilization
                         - res["coop"].useful_utilization)
        rows.append(row(
            f"themis/ia{period:g}/preempt_vs_coop", 0.0,
            f"hi_p95_speedup={speedup:.2f}x "
            f"util_delta={util_delta:+.3f} "
            f"goodput_delta={goodput_delta:+.3f} "
            f"miss_delta={res['preempt'].deadline_miss_rate - res['coop'].deadline_miss_rate:+.3f}"))
        # checkpointing vs plain preemption: how much of the previously
        # discarded slot-time the context saves bring back, at what p95
        d_pre = res["preempt"].discarded_ms
        d_ck = res["preempt+ckpt"].discarded_ms
        # nothing discarded -> nothing to reclaim: vacuously perfect
        # (the gate must not fail a trace with zero evicted work)
        reclaim_frac = 1.0 - d_ck / d_pre if d_pre > 0 else 1.0
        p95_pre = res["preempt"].p95_latency(priority=PRIORITY_HI)
        p95_ck = res["preempt+ckpt"].p95_latency(priority=PRIORITY_HI)
        rows.append(row(
            f"themis/ia{period:g}/ckpt_vs_preempt", 0.0,
            f"reclaim_frac={reclaim_frac:.2f} "
            f"(discarded {d_pre:.0f}->{d_ck:.0f}ms) "
            f"saves={res['preempt+ckpt'].ckpt_saves} "
            f"restores={res['preempt+ckpt'].ckpt_restores} "
            f"hi_p95={p95_pre:.1f}->{p95_ck:.1f}ms "
            f"goodput_delta="
            f"{res['preempt+ckpt'].useful_utilization - res['preempt'].useful_utilization:+.3f}"))
        rows.append(row(
            f"themis/ia{period:g}/reserve_vs_coop", 0.0,
            f"hi_p95={res['coop'].p95_latency(priority=PRIORITY_HI):.1f}"
            f"->{res['reserve'].p95_latency(priority=PRIORITY_HI):.1f}ms "
            f"util_delta="
            f"{res['reserve'].utilization - res['coop'].utilization:+.3f} "
            f"preemptions={res['reserve'].preemptions}"))
        if ckpt_gate and period == 10.0:
            if reclaim_frac < RECLAIM_GATE:
                print(f"FAIL: checkpointing reclaimed only "
                      f"{reclaim_frac:.2f} of discarded slot-time "
                      f"(acceptance: >={RECLAIM_GATE})", file=sys.stderr)
                sys.exit(1)
            if p95_ck > p95_pre + 1e-9:
                print(f"FAIL: checkpointing regressed hi-prio p95 "
                      f"({p95_pre:.2f} -> {p95_ck:.2f} ms)",
                      file=sys.stderr)
                sys.exit(1)
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv[1:],
         ckpt_gate="--ckpt" in sys.argv[1:])
