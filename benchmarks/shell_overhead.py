"""Paper Table 1 analogue: shell resource overhead.

On FPGA the shell burns 20-50% of fabric; on TPU the FOS-JAX shell is host
software + geometry, so the figure of merit is slot *coverage* of the mesh
(chips schedulable for accelerators) and shell bring-up latency.
"""
from __future__ import annotations

import time

from benchmarks.common import row, timeit
from repro.core.shell import production_shells, Shell, uniform_shell


def main() -> list[str]:
    rows = []
    for name, spec in production_shells().items():
        cover = spec.coverage()
        rows.append(row(f"table1/coverage/{name}", 0.0,
                        f"{cover:.3f}"))
    # shell bring-up ("load shell") on the host: bind 1-device shell
    spec = uniform_shell("host1_s1", (1, 1), 1)
    t = timeit(lambda: Shell(spec), iters=10)
    rows.append(row("table1/shell_bringup", t * 1e6, "host-bind"))
    return rows


if __name__ == "__main__":
    main()
