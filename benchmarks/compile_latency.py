"""Paper Table 3 analogue: decoupled compilation vs per-slot recompilation.

FOS claim: compile a module ONCE against the slot interface; relocation to
other congruent slots is (nearly) free via bitstream manipulation.  Standard
flow: compile the module separately for *each* region.

FOS-JAX measurement (subprocess with 8 host devices, shell host8_s4):
  - xilinx-flow analogue: place the module on slots 0..2 with a cold
    compilation cache each time  -> 3 full compiles;
  - FOS analogue: first compile (against the congruence class), then
    relocations to slots 1..2 with the XLA compilation cache warm.
Derived figure = speedup of the FOS flow for 3 regions (paper: 1.74-2.34x).
"""
from __future__ import annotations

from benchmarks.common import row, run_subprocess

_CODE = r"""
import time, json, tempfile, os
import jax
jax.config.update("jax_compilation_cache_dir", tempfile.mkdtemp())
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
from repro.core import Shell, uniform_shell
from repro.core.module import AccelModule
from repro.core import zoo

shell = Shell(uniform_shell("host8_s4", (1, 8), 4))
results = {}

# --- standard-flow analogue: independent compile per region (cold caches) ---
t_cold = []
for i in range(3):
    mod = AccelModule(f"mandel_cold_{i}", zoo.build_mandelbrot, [1])
    t0 = time.perf_counter()
    mod.place(shell.slots[i], 1)
    t_cold.append(time.perf_counter() - t0)

# --- FOS flow: compile once, relocate to congruent slots (warm cache) ------
mod = AccelModule("mandel_fos", zoo.build_mandelbrot, [1])
t0 = time.perf_counter(); mod.place(shell.slots[0], 1)
t_first = time.perf_counter() - t0
t_reloc = []
for i in (1, 2):
    t0 = time.perf_counter(); mod.place(shell.slots[i], 1)
    t_reloc.append(time.perf_counter() - t0)

results = {
    "xilinx_total": sum(t_cold),
    "fos_total": t_first + sum(t_reloc),
    "first_compile": t_first,
    "reloc_mean": sum(t_reloc) / len(t_reloc),
}
print("RESULT::" + json.dumps(results))
"""


def main() -> list[str]:
    out = run_subprocess(_CODE, device_count=8)
    import json
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT::")][0][8:])
    speedup = res["xilinx_total"] / res["fos_total"]
    rows = [
        row("table3/xilinx_flow_3regions", res["xilinx_total"] * 1e6,
            "3 independent compiles"),
        row("table3/fos_flow_3regions", res["fos_total"] * 1e6,
            f"speedup={speedup:.2f}x"),
        row("table3/first_compile", res["first_compile"] * 1e6, "cold"),
        row("table3/relocation", res["reloc_mean"] * 1e6,
            f"vs_cold={res['first_compile'] / max(res['reloc_mean'], 1e-9):.1f}x"),
    ]
    return rows


if __name__ == "__main__":
    main()
