"""Paper Fig. 19-21 analogue: single-tenant scaling with replication and
varying exposed parallelism.

Two layers of evidence (this container has ONE physical core, so concurrent
slot execution timeshares it — live wall-clock cannot show parallel
speedup):
  1. LIVE (subprocess, 4 host devices, shell host4_s4): correctness +
     scheduling behaviour when one tenant exposes 1..8 chunks; measures
     per-chunk service latency and verifies all slots get used.
  2. CALIBRATED SIM: per-chunk latency measured live feeds the cost model;
     the simulator then reports the scaling curve the policy achieves on
     hardware where slots are truly parallel (the paper's Fig 20/21 shape:
     linear until #slots, then time-multiplexing plateau).
"""
from __future__ import annotations

import json

from benchmarks.common import row, run_subprocess
from repro.core import ImplAlt, ModuleDescriptor, PolicyConfig, Registry, \
    SimJob, simulate

_LIVE = r"""
import json, time
import numpy as np
from repro.core import Daemon, Shell, default_registry, uniform_shell

shell = Shell(uniform_shell("host4_s4", (1, 4), 4))
reg = default_registry()
d = Daemon(shell, reg)
re = np.zeros((256, 256), np.float32)
# warm the module on every slot
h = d.submit("warm", "mandelbrot", [(re, re)] * 8)
h.future.result(600)
out = {}
for n_req in (1, 2, 3, 4, 6, 8):
    t0 = time.perf_counter()
    h = d.submit("u0", "mandelbrot", [(re, re)] * n_req)
    h.future.result(600)
    out[n_req] = time.perf_counter() - t0
slots_used = len({r[0] for r in
                  [(k[0],) for k in d._placements.keys()]})
out["slots_used"] = slots_used
d.shutdown()
print("RESULT::" + json.dumps(out))
"""


def main() -> list[str]:
    rows = []
    out = run_subprocess(_LIVE, device_count=4)
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT::")][0][8:])
    slots_used = res.pop("slots_used")
    per_chunk = res["1"]
    for n_req, t in sorted(res.items(), key=lambda kv: int(kv[0])):
        rows.append(row(f"fig20/live/{n_req}_requests", t * 1e6,
                        f"rel={t / res['1']:.2f}"))
    rows.append(row("fig20/live/slots_used", 0.0, slots_used))

    # calibrated simulation: FIXED frame of work exposed at varying
    # parallelism on 4 truly-parallel slots (paper Fig 20/21 semantics)
    frame_ms = per_chunk * 1e3          # live-calibrated frame cost
    overhead = frame_ms * 0.04
    base = None
    for n_req in (1, 2, 3, 4, 6, 8, 12):
        reg = Registry()
        reg.register_module(ModuleDescriptor(
            name="mandelbrot", entrypoint="x:y",
            impls=(ImplAlt("x1", 1, frame_ms / n_req + overhead),)))
        r = simulate(reg, 4, [SimJob(0.0, "u0", "mandelbrot", n_req)],
                     PolicyConfig(reconfig_penalty_ms=overhead))
        base = base or r.makespan
        rows.append(row(f"fig21/sim/{n_req}_chunks",
                        r.makespan * 1e3,
                        f"frame_rel={r.makespan / base:.2f}"))
    return rows


if __name__ == "__main__":
    main()
