"""Paper Fig. 15 analogue: resource-elastic vs standard fixed scheduling.

Replays the figure's scenario shape (tasks A-D arriving/completing on a
4-region shell) through the real scheduler policy in the discrete-event
simulator and reports utilization / makespan / mean latency for both
policies.
"""
from __future__ import annotations

from benchmarks.common import row
from repro.core import ImplAlt, ModuleDescriptor, PolicyConfig, Registry, \
    SimJob, simulate


def _registry() -> Registry:
    reg = Registry()
    for name, base in (("taskA", 12.0), ("taskB", 10.0), ("taskC", 8.0),
                       ("taskD", 9.0)):
        reg.register_module(ModuleDescriptor(
            name=name, entrypoint="x:y",
            impls=(ImplAlt("x1", 1, base),
                   ImplAlt("x2", 2, base * 0.55),
                   ImplAlt("x4", 4, base * 0.30))))
    return reg


def scenario() -> list[SimJob]:
    return [
        SimJob(0.0, "userA", "taskA", 6),
        SimJob(0.0, "userB", "taskB", 4),
        SimJob(18.0, "userC", "taskC", 5),   # circled event 2: new arrival
        SimJob(40.0, "userD", "taskD", 3),   # circled event 3
    ]


def main() -> list[str]:
    reg = _registry()
    rows = []
    res = {}
    for name, pol in (("elastic", PolicyConfig(elastic=True)),
                      ("fixed", PolicyConfig(elastic=False))):
        r = simulate(reg, 4, scenario(), pol)
        res[name] = r
        rows.append(row(f"fig15/{name}/makespan", r.makespan * 1e3,
                        f"util={r.utilization:.3f}"))
        rows.append(row(f"fig15/{name}/mean_latency",
                        r.mean_latency * 1e3,
                        f"reconfigs={r.reconfigurations}"))
    gain = res["fixed"].makespan / res["elastic"].makespan
    util_gain = res["elastic"].utilization - res["fixed"].utilization
    rows.append(row("fig15/elastic_vs_fixed", 0.0,
                    f"makespan_speedup={gain:.2f}x "
                    f"util_delta={util_gain:+.3f}"))
    return rows


if __name__ == "__main__":
    main()
