"""Simulator event throughput: incremental dirty-shell core vs full
rescheduling.

PR 6 rebuilt the simulator/fabric scheduling loop around an event heap
with a *dirty-shell set*: a scheduling pass after each event visits only
the shells whose fixpoint could have moved (arrival dispatched to it,
chunk completion, preemption, checkpoint consume, party to a steal,
reservation resample, starvation-aging wake).  Clean shells are skipped
— provably a no-op elision, pinned byte-for-byte by the golden-trace
corpus (tests/fixtures/sim_golden_*.json) and the old-vs-new
equivalence property in tests/test_simulator_core.py.

This benchmark measures the payoff: events/second replaying one large
mixed trace (preemption + stealing + checkpointing + adaptive
reservation, heterogeneous shell speeds) through the same `Fabric` in
both modes:

  - **incremental**: the default dirty-shell core;
  - **full**: `Fabric.full_reschedule = True` — every shell reschedules
    on every pass, the pre-PR 6 control flow.  This baseline still
    benefits from PR 6's satellite speedups (allocator bitmask,
    steal-fail cache, O(1) pending counts), so beating it is *stricter*
    than beating the true pre-refactor core.

The two runs must produce byte-identical `SimResult`s (enforced) — the
speedup is pure control-flow elision, not a behavior change.  An event
here is one heap pop that did work: `n_jobs` arrivals plus one "done"
per dispatched chunk (completed -> timeline, evicted -> preempted
spans); both modes replay the identical event sequence, so the
events/sec ratio equals the wall-time ratio.

Acceptance (CI runs `--quick`): the incremental core must clear
**>= 3x** events/sec over the full-reschedule baseline.  The advantage
scales with shell count — each event dirties O(1) shells, so full
rescheduling does ~n_shells times the placement work per event.

Writes `BENCH_6.json` (events/sec both modes, speedup, trace shape)
unless `--out ''`.
"""
from __future__ import annotations

import argparse
import dataclasses
import random
import sys
import time

from benchmarks.common import row, write_bench
from repro.core import Fabric, ImplAlt, ModuleDescriptor, PolicyConfig, \
    Registry, SimJob, simulate

SPEEDS = (1.0, 2.0, 0.5)       # heterogeneous shell clocks, cycled
GATE = 3.0                     # events/sec speedup acceptance bound


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("b1", 1, 40.0), ImplAlt("b2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("i1", 1, 4.0), ImplAlt("i2", 2, 2.4))))
    reg.register_module(ModuleDescriptor(
        name="wide", entrypoint="x:y",
        impls=(ImplAlt("w2", 2, 10.0),)))
    return reg


def mixed_trace(n_jobs: int, n_tenants: int, seed: int,
                gap_ms: float) -> list[SimJob]:
    """Strictly-increasing arrivals (exponential gaps), 50% batch /
    30% interactive (prio 2, 30 ms deadline) / 20% wide (prio 1)."""
    rng = random.Random(seed)
    jobs, t = [], 0.0
    for i in range(n_jobs):
        t += rng.expovariate(1.0 / gap_ms) + 1e-3
        tenant = f"t{rng.randrange(n_tenants)}"
        u = rng.random()
        if u < 0.5:
            jobs.append(SimJob(t, tenant, "batch", rng.randint(4, 10)))
        elif u < 0.8:
            jobs.append(SimJob(t, tenant, "inter", rng.randint(1, 3),
                               priority=2, deadline_ms=30.0))
        else:
            jobs.append(SimJob(t, tenant, "wide", rng.randint(2, 5),
                               priority=1))
    return jobs


def _policy() -> PolicyConfig:
    return PolicyConfig(preemptive=True, steal=True, ckpt=True,
                        reserve_mode="adaptive", reserve_slots_max=2,
                        transfer_ms=1.0)


def run_once(n_shells: int, jobs: list[SimJob],
             full: bool) -> tuple[float, object]:
    """One timed replay; returns (wall seconds, SimResult)."""
    reg = _registry()
    shells = {f"s{i:02d}": (4, SPEEDS[i % len(SPEEDS)])
              for i in range(n_shells)}
    fab = Fabric(shells, reg, _policy())
    fab.full_reschedule = full
    t0 = time.perf_counter()
    res = simulate(reg, fab, jobs)
    return time.perf_counter() - t0, res


def n_events(res) -> int:
    """Heap pops that did work: arrivals + one done per dispatched
    chunk (completions land in `timeline`, evictions in
    `preempted_spans`)."""
    return len(res.request_meta) + len(res.timeline) \
        + len(res.preempted_spans)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace for CI smoke (gate still on)")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the >=3x acceptance exit")
    ap.add_argument("--out", default="BENCH_6.json",
                    help="result JSON path ('' disables)")
    args = ap.parse_args(argv)

    # 24 heterogeneous shells under a saturating arrival rate (1 ms
    # mean gap): the backlog stays deep, so full rescheduling pays its
    # O(n_shells x queue_depth) placement scan on every event while the
    # dirty-shell core touches O(1) shells.  Shallow traces (few
    # shells, light load) measure ~1.7x — the elision matters exactly
    # when the fabric is large and busy.
    n_shells = 24
    n_jobs = 600 if args.quick else 1200
    gap_ms = 1.0
    jobs = mixed_trace(n_jobs, n_tenants=16, seed=7, gap_ms=gap_ms)

    # incremental first (also serves as interpreter warmup for the
    # slower baseline — ordering biases *against* the measured speedup)
    t_inc, res_inc = run_once(n_shells, jobs, full=False)
    t_full, res_full = run_once(n_shells, jobs, full=True)

    if dataclasses.asdict(res_inc) != dataclasses.asdict(res_full):
        print("FAIL: incremental and full-reschedule runs diverged — "
              "the dirty-shell elision changed behavior", file=sys.stderr)
        return 1

    ev = n_events(res_inc)
    eps_inc = ev / t_inc
    eps_full = ev / t_full
    speedup = eps_inc / eps_full
    row("sim_throughput/incremental/events_per_sec", t_inc / ev * 1e6,
        f"events_per_sec={eps_inc:.0f} events={ev} wall={t_inc:.2f}s")
    row("sim_throughput/full_reschedule/events_per_sec",
        t_full / ev * 1e6,
        f"events_per_sec={eps_full:.0f} events={ev} wall={t_full:.2f}s")
    row("sim_throughput/speedup", 0.0,
        f"speedup={speedup:.2f}x (acceptance: >={GATE:.0f}x) "
        f"shells={n_shells} jobs={n_jobs} "
        f"preemptions={res_inc.preemptions} "
        f"stolen={res_inc.stolen_chunks} "
        f"ckpt_restores={res_inc.ckpt_restores} identical=True")

    write_bench(args.out, 6, "sim_throughput", metrics={
        "trace": {"n_shells": n_shells, "slots_per_shell": 4,
                  "speeds": list(SPEEDS), "n_jobs": n_jobs,
                  "n_tenants": 16, "seed": 7, "gap_ms": gap_ms,
                  "quick": args.quick},
        "events": ev,
        "incremental": {"wall_s": round(t_inc, 4),
                        "events_per_sec": round(eps_inc, 1)},
        "full_reschedule": {"wall_s": round(t_full, 4),
                            "events_per_sec": round(eps_full, 1)},
        "identical_results": True,
        "makespan_ms": round(res_inc.makespan, 3),
        "preemptions": res_inc.preemptions,
        "stolen_chunks": res_inc.stolen_chunks,
        "ckpt_restores": res_inc.ckpt_restores,
    }, gates={"speedup_min": GATE, "speedup": round(speedup, 3),
              "pass": speedup >= GATE})

    if not args.no_gate and speedup < GATE:
        print(f"FAIL: incremental core speedup {speedup:.2f}x < "
              f"{GATE:.0f}x over full rescheduling", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
