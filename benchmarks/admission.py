"""SLO admission control under overload: contract p95 vs naive sharing.

PR 7 added per-tenant QoS contracts (`QoSContract`) and a predictive
`AdmissionController` that screens every `Fabric.submit` against the
registered contracts: a submit whose predicted completion would push any
contract past its deadline percentile is REJECTED (or transparently
DEGRADEd when the contract names a cheaper implementation).  The point
of admission control is what happens under *overload*: without it,
excess batch work queues in front of everyone and the latency-sensitive
tenant's tail grows without bound; with it, the controller sheds exactly
the work that would breach the contract, so the contract tenant's p95
stays pinned near its uncontended value no matter how much load is
offered.

This benchmark sweeps offered load from 0.5x to 3x fabric capacity.
At each point the same seeded trace — one contract tenant ("svc", a
steady interactive stream) plus background batch tenants sized to the
overload factor — runs twice through identical fabrics:

  - **admission**: svc's `QoSContract` is registered; the controller
    screens every submit (svc's own and the background tenants').
  - **naive**: no contract; every job is admitted FIFO into the same
    elastic scheduler.

The figure of merit is svc's p95 latency over its *admitted* jobs,
normalised to the uncontended (0.5x, admission) p95.

Acceptance (CI runs `--quick`): at 2x overload the admitted-contract
p95 must stay within **1.3x** of uncontended while the naive p95
exceeds **3x** — i.e. the controller is doing real work exactly where
fair sharing collapses.

Writes `BENCH_7.json` (per-factor p95/shed-rate both modes, gate
verdict) unless `--out ''`.
"""
from __future__ import annotations

import argparse
import random
import sys

from benchmarks.common import row, write_bench
from repro.core import Fabric, ImplAlt, ModuleDescriptor, PolicyConfig, \
    QoSContract, Registry, SimJob, simulate

FACTORS = (0.5, 1.0, 1.5, 2.0, 2.5, 3.0)
GATE_FACTOR = 2.0         # the overload point the acceptance gate reads
GATE_ADMIT = 1.3          # admitted p95 must stay within this x uncontended
GATE_NAIVE = 3.0          # ...while naive p95 exceeds this x uncontended

SVC_GAP_MS = 10.0         # svc inter-arrival (rate 100/s)
SVC_SERVICE = 4.0         # svc per-chunk estimate at footprint 1
BG_CHUNKS = 4             # background batch chunks per job
BG_SERVICE = 40.0         # background per-chunk estimate at footprint 1


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("b1", 1, BG_SERVICE), ImplAlt("b2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("i1", 1, SVC_SERVICE),)))
    return reg


def _fabric(reg: Registry) -> tuple[Fabric, float]:
    """Two-shell fabric; returns it with its capacity in slot-ms/ms."""
    pol = PolicyConfig(preemptive=True, transfer_ms=1.0)
    shells = {"s0": (4, 1.0), "s1": (4, 1.0)}
    cap = sum(n * speed for n, speed in shells.values())
    return Fabric(shells, reg, pol), cap


def overload_trace(factor: float, horizon_ms: float,
                   seed: int) -> list[SimJob]:
    """svc's steady interactive stream plus background batch tenants
    whose offered slot-ms/ms tops total load up to `factor` x capacity.
    Arrival gaps are seeded-exponential and strictly increasing."""
    _, cap = _fabric(_registry())
    svc_load = SVC_SERVICE / SVC_GAP_MS
    bg_load = max(0.0, factor * cap - svc_load)
    bg_gap = (BG_CHUNKS * BG_SERVICE) / bg_load if bg_load else None
    rng = random.Random(seed)
    jobs = []
    t = 0.0
    while t < horizon_ms:
        t += rng.expovariate(1.0 / SVC_GAP_MS) + 1e-3
        jobs.append(SimJob(t, "svc", "inter", 1, priority=3))
    if bg_gap is not None:
        t = 0.0
        i = 0
        while t < horizon_ms:
            t += rng.expovariate(1.0 / bg_gap) + 1e-3
            jobs.append(SimJob(t, f"bg{i % 3}", "batch", BG_CHUNKS))
            i += 1
    jobs.sort(key=lambda j: j.t_arrive)
    # strictly increasing timestamps (merge of two streams can collide)
    last = -1.0
    fixed = []
    for j in jobs:
        t = j.t_arrive if j.t_arrive > last else last + 1e-3
        fixed.append(SimJob(t, j.tenant, j.module, j.n_chunks,
                            priority=j.priority))
        last = t
    return fixed


def _p95(xs: list[float]) -> float:
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(0.95 * len(xs)))] if xs else 0.0


def run_point(factor: float, horizon_ms: float, seed: int,
              admission: bool) -> dict:
    """One sweep point; returns svc p95 over admitted jobs + shed rate."""
    reg = _registry()
    fab, _ = _fabric(reg)
    if admission:
        fab.register_contract(QoSContract(
            "svc", rate_per_s=1000.0 / SVC_GAP_MS, deadline_ms=60.0))
    jobs = overload_trace(factor, horizon_ms, seed)
    res = simulate(reg, fab, jobs)
    svc_lat = [lat for rid, lat in res.request_latency.items()
               if fab.jobs[rid].tenant == "svc"]
    n_svc = sum(1 for j in fab.jobs.values() if j.tenant == "svc")
    rejected = sum(1 for j in fab.jobs.values() if j.rejected)
    return {"factor": factor, "admission": admission,
            "svc_p95_ms": round(_p95(svc_lat), 3),
            "svc_admitted": len(svc_lat), "svc_offered": n_svc,
            "rejected_jobs": rejected, "n_jobs": len(jobs),
            "makespan_ms": round(res.makespan, 3)}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="shorter horizon for CI smoke (gate still on)")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the acceptance exit")
    ap.add_argument("--out", default="BENCH_7.json",
                    help="result JSON path ('' disables)")
    args = ap.parse_args(argv)

    horizon = 3000.0 if args.quick else 12000.0
    sweep = []
    for f in FACTORS:
        adm = run_point(f, horizon, seed=11, admission=True)
        nai = run_point(f, horizon, seed=11, admission=False)
        sweep.append({"factor": f, "admission": adm, "naive": nai})

    base = sweep[0]["admission"]["svc_p95_ms"]   # uncontended reference
    for pt in sweep:
        a, n = pt["admission"], pt["naive"]
        row(f"admission/x{pt['factor']:g}/svc_p95_ms", a["svc_p95_ms"],
            f"admitted_p95={a['svc_p95_ms']}ms "
            f"({a['svc_p95_ms'] / base:.2f}x uncontended) "
            f"naive_p95={n['svc_p95_ms']}ms "
            f"({n['svc_p95_ms'] / base:.2f}x) "
            f"shed={a['rejected_jobs']}/{a['n_jobs']}")

    gate_pt = next(p for p in sweep if p["factor"] == GATE_FACTOR)
    adm_x = gate_pt["admission"]["svc_p95_ms"] / base
    nai_x = gate_pt["naive"]["svc_p95_ms"] / base
    ok = adm_x <= GATE_ADMIT and nai_x > GATE_NAIVE
    row("admission/gate", 0.0,
        f"at {GATE_FACTOR:g}x overload: admitted {adm_x:.2f}x uncontended"
        f" (bound <={GATE_ADMIT}x), naive {nai_x:.2f}x "
        f"(bound >{GATE_NAIVE:g}x) -> {'PASS' if ok else 'FAIL'}")

    write_bench(args.out, 7, "admission", metrics={
        "trace": {"svc_gap_ms": SVC_GAP_MS,
                  "svc_service_ms": SVC_SERVICE,
                  "bg_chunks": BG_CHUNKS,
                  "bg_service_ms": BG_SERVICE,
                  "horizon_ms": horizon, "seed": 11,
                  "quick": args.quick},
        "contract": {"tenant": "svc",
                     "rate_per_s": 1000.0 / SVC_GAP_MS,
                     "deadline_ms": 60.0, "percentile": 0.95},
        "sweep": sweep,
        "uncontended_p95_ms": base,
    }, gates={"factor": GATE_FACTOR,
              "admitted_bound_x": GATE_ADMIT,
              "naive_bound_x": GATE_NAIVE,
              "admitted_x": round(adm_x, 3),
              "naive_x": round(nai_x, 3),
              "pass": ok})

    if not args.no_gate and not ok:
        print(f"FAIL: at {GATE_FACTOR:g}x overload admitted-contract "
              f"p95 is {adm_x:.2f}x uncontended (bound "
              f"<={GATE_ADMIT}x) and naive is {nai_x:.2f}x (bound "
              f">{GATE_NAIVE:g}x)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
