"""Congestion-aware stealing vs the scalar transfer model (PR 10 gate).

One victim shell holds a deep pinned batch backlog on switch `sw_v`;
six single-slot thief shells sit idle across a thin trunk on `sw_t`.
Every steal moves its payload over the shared trunk, where concurrent
transfers serialize and queue (`core/network.py` bounded store-and-
forward links).

The same trace replays twice on the *same physical topology* — both
runs pay realized link occupancy; only the steal gate's belief differs:

  - **aware** (`congestion_aware=True`, the default): the gate reads
    load-aware estimates — queue wait counts, and a full trunk buffer
    estimates `inf` — so thieves stagger their pulls and back off while
    the trunk is saturated;
  - **scalar** (`congestion_aware=False`): the gate believes the
    zero-load figure, exactly what the old scalar `transfer_ms` model
    believed.  All six thieves fire at once, their transfers stack up
    on the trunk, and each stolen chunk pays a realized per-chunk price
    far above the estimate the gate saw.

Acceptance (CI): the congestion-aware run must beat the scalar-belief
run by >= 1.2x makespan on the contended trace, and the scalar run must
actually queue transfers (otherwise the trace stopped exercising
contention and the comparison is vacuous).
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import row, write_bench
from repro.core import Fabric, FabricNetwork, ImplAlt, ModuleDescriptor, \
    PolicyConfig, Registry, SimJob, simulate
from repro.obs import FlightRecorder

GATE = 1.2
N_THIEVES = 6

# thin trunk: one chunk of payload costs ~2.5x a batch chunk's service
# time at zero load — still worth stealing against a deep victim
# backlog, so the scalar belief fires every thief at once and their
# pulls serialize into multiples of that on the two-deep trunk buffer
TOPOLOGY = {
    "switches": ["sw_v", "sw_t"],
    "ports": {"victim": "sw_v",
              **{f"thief{i}": "sw_t" for i in range(N_THIEVES)}},
    "default_link": {"latency_ms": 0.5, "bw_ms": 0.5, "buffer": 8},
    "links": [{"src": "sw_v", "dst": "sw_t",
               "latency_ms": 2.0, "bw_ms": 100.0, "buffer": 2}],
}


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    return reg


def contended_trace(n_jobs: int) -> list[SimJob]:
    """Deep batch backlog pinned to the victim; the thieves' only work
    is what they steal across the trunk."""
    return [SimJob(2.0 * i, f"t{i % 3}", "batch", 6, affinity="victim")
            for i in range(n_jobs)]


def run_once(n_jobs: int, aware: bool):
    reg = _registry()
    shells = {"victim": (4, 1.0),
              **{f"thief{i}": (1, 1.0) for i in range(N_THIEVES)}}
    net = FabricNetwork.from_topology(
        TOPOLOGY, shells)
    fab = Fabric(shells, reg, PolicyConfig(congestion_aware=aware),
                 network=net)
    rec = FlightRecorder(trace=False).attach(fab)
    res = simulate(reg, fab, contended_trace(n_jobs))
    return res, rec.snapshot()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller backlog for CI smoke (gate still on)")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the >=1.2x acceptance exit")
    ap.add_argument("--out", default="BENCH_10.json",
                    help="result JSON path ('' disables)")
    args = ap.parse_args(argv)

    n_jobs = 8 if args.quick else 16
    out = {}
    for name, aware in (("aware", True), ("scalar", False)):
        res, snap = run_once(n_jobs, aware)
        c = snap["counters"]
        out[name] = (res, c)
        row(f"network_contention/{name}/makespan", res.makespan * 1e3,
            f"stolen={res.stolen_chunks} "
            f"steals={c['steal_hits']} "
            f"queued={c['transfers_queued']} "
            f"util={res.utilization:.3f}")

    aware_res, aware_c = out["aware"]
    scalar_res, scalar_c = out["scalar"]
    speedup = scalar_res.makespan / max(aware_res.makespan, 1e-9)
    row("network_contention/aware_vs_scalar", 0.0,
        f"makespan_speedup={speedup:.2f}x (acceptance: >={GATE}x) "
        f"stolen={aware_res.stolen_chunks}vs{scalar_res.stolen_chunks} "
        f"queued={aware_c['transfers_queued']}"
        f"vs{scalar_c['transfers_queued']}")

    write_bench(args.out, 10, "network_contention", metrics={
        "trace": {"n_jobs": n_jobs, "n_thieves": N_THIEVES,
                  "quick": args.quick},
        "aware": {"makespan_ms": round(aware_res.makespan, 3),
                  "stolen_chunks": aware_res.stolen_chunks,
                  "transfers_queued": aware_c["transfers_queued"]},
        "scalar": {"makespan_ms": round(scalar_res.makespan, 3),
                   "stolen_chunks": scalar_res.stolen_chunks,
                   "transfers_queued": scalar_c["transfers_queued"]},
    }, gates={"speedup_min": GATE, "speedup": round(speedup, 3),
              "scalar_queued_min": 1,
              "scalar_queued": scalar_c["transfers_queued"],
              "pass": speedup >= GATE
              and scalar_c["transfers_queued"] >= 1})

    if args.no_gate:
        return 0
    if scalar_c["transfers_queued"] < 1:
        print("FAIL: the scalar-belief run queued no transfers — the "
              "trace no longer exercises trunk contention",
              file=sys.stderr)
        return 1
    if speedup < GATE:
        print(f"FAIL: congestion-aware stealing speedup {speedup:.2f}x "
              f"< {GATE}x over the scalar belief", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
