"""Roofline report (Figs 17/18 + the perf deliverable): reads the dry-run
artifacts and emits per-(arch x shape) roofline terms for the single-pod
mesh.  `python -m benchmarks.roofline --markdown` renders the EXPERIMENTS.md
table.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.common import REPO, row

ART = REPO / "benchmarks" / "artifacts" / "dryrun"


def load_cells(mesh: str = "single", tag: str | None = None) -> list[dict]:
    cells = []
    for p in sorted((ART / mesh).glob("*/*.json")):
        if tag in (None, "baseline") and "__" in p.name:
            continue            # tagged variant files
        if tag not in (None, "baseline") and not p.name.endswith(
                f"__{tag}.json"):
            continue
        cells.append(json.loads(p.read_text()))
    return cells


def terms_of(d: dict):
    """Re-derive roofline terms from a stored artifact with the CURRENT
    roofline model (kernel adjustment etc. are analytic — no recompile)."""
    from repro import configs
    from repro.configs.common import apply_cell_policy
    from repro.launch import roofline_model
    from repro.models.api import SHAPE_CELLS
    e = d["extrapolated"]
    cell = SHAPE_CELLS[d["cell"]]
    cfg = apply_cell_policy(configs.get(d["arch"]), cell)
    return roofline_model.terms_from_costs(
        e["flops_per_device"], e["bytes_per_device"],
        e["coll_bytes_per_device"], d["chips"], cfg, cell)


def main() -> list[str]:
    rows = []
    for d in load_cells("single"):
        name = f"roofline/{d['arch']}/{d['cell']}"
        if "skipped" in d:
            rows.append(row(name, 0.0, "SKIP(full-attention)"))
            continue
        if "error" in d or "extrapolated" not in d:
            rows.append(row(name, 0.0, f"ERROR:{d.get('error', '?')[:60]}"))
            continue
        t = terms_of(d)
        rows.append(row(name, t.step_time_s * 1e6,
                        f"dominant={t.dominant} "
                        f"frac={t.roofline_fraction:.3f} "
                        f"useful={t.useful_flops_ratio:.2f}"))
    return rows


def markdown(tag: str | None = None) -> str:
    lines = [
        "| arch | shape | compute (ms) | memory XLA-path (ms) | "
        "memory kernel-adj (ms) | collective (ms) | dominant | MODEL_FLOPS "
        "| useful ratio | roofline frac | fits HBM |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in load_cells("single", tag):
        if "skipped" in d:
            lines.append(f"| {d['arch']} | {d['cell']} | — | — | — | — | — "
                         f"| — | — | — | SKIP(full-attention) |")
            continue
        if "error" in d or "extrapolated" not in d:
            lines.append(f"| {d['arch']} | {d['cell']} | — | — | — | — | — "
                         f"| — | — | — | ERROR |")
            continue
        t = terms_of(d)
        mem = d["full"]["memory"]
        per_dev = (mem["argument_size_in_bytes"]
                   + mem["temp_size_in_bytes"]
                   + mem["output_size_in_bytes"]
                   - mem["alias_size_in_bytes"])
        fits = "yes" if per_dev < 16 * 1024 ** 3 else \
            f"NO ({per_dev / 1024**3:.1f} GiB)"
        lines.append(
            f"| {d['arch']} | {d['cell']} | {t.compute_s * 1e3:.2f} "
            f"| {t.memory_s * 1e3:.2f} "
            f"| {t.memory_kernel_adj_s * 1e3:.2f} "
            f"| {t.collective_s * 1e3:.2f} | {t.dominant} "
            f"| {t.model_flops_global:.3g} "
            f"| {t.useful_flops_ratio:.2f} "
            f"| {t.roofline_fraction:.3f} | {fits} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    if args.markdown:
        print(markdown(args.tag))
    else:
        main()
