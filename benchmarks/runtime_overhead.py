"""Paper Table 4 analogue: software-stack execution overheads.

  - daemon init (once)            ~ paper "Initialize gRPC" 12.2 ms
  - registry JSON parse (once)    ~ paper "JSON parsing"     2.27 ms
  - submit -> dispatch            ~ paper "gRPC call"        0.71 ms
  - scheduler decision            ~ paper "Scheduler"        0.02 ms
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, timeit
from repro.core import Daemon, Registry, Shell, default_registry, \
    uniform_shell
from repro.core.scheduler import PolicyConfig, SchedulerState


def main() -> list[str]:
    rows = []
    # registry parse
    import tempfile
    reg = default_registry()
    with tempfile.TemporaryDirectory() as d:
        reg.save(d)
        t = timeit(lambda: Registry.load(d), iters=20)
    rows.append(row("table4/json_parse_once", t * 1e6, "registry load"))

    # daemon init
    spec = uniform_shell("host1_s1", (1, 1), 1)
    t0 = time.perf_counter()
    daemon = Daemon(Shell(spec), reg)
    t_init = time.perf_counter() - t0
    rows.append(row("table4/daemon_init_once", t_init * 1e6, "init"))

    # submit -> daemon call overhead (excluding execution): measure submit()
    re = np.zeros((256, 256), np.float32)
    t = timeit(lambda: daemon.submit("bench", "mandelbrot",
                                     [(re, re)]).future.result(300),
               warmup=2, iters=5)
    rows.append(row("table4/call_roundtrip", t * 1e6,
                    "submit+sched+exec+result"))
    t_sub = timeit(lambda: daemon.submit("bench2", "mandelbrot",
                                         [(re, re)]), iters=5)
    rows.append(row("table4/submit_only", t_sub * 1e6, "enqueue"))
    time.sleep(2)

    # scheduler decision latency (pure policy, no execution)
    state = SchedulerState(8, reg, PolicyConfig())
    for u in range(4):
        state.submit(f"u{u}", "mandelbrot", 16)
    t0 = time.perf_counter_ns()
    n = 0
    while True:
        a = state.schedule()
        if not a:
            break
        for x in a:
            state.complete(x)
        n += 1
        if n > 200:
            break
    dt = (time.perf_counter_ns() - t0) / max(n, 1)
    rows.append(row("table4/scheduler_decision", dt / 1e3,
                    f"{n}_rounds"))
    if daemon.stats["sched_calls"]:
        us = daemon.stats["sched_ns"] / daemon.stats["sched_calls"] / 1e3
        rows.append(row("table4/daemon_sched_observed", us, "per event"))
    daemon.shutdown()
    return rows


if __name__ == "__main__":
    main()
