"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import sys
import traceback


BENCHES = [
    ("table1", "benchmarks.shell_overhead"),
    ("table2", "benchmarks.bus_adaptors"),
    ("table3", "benchmarks.compile_latency"),
    ("table4", "benchmarks.runtime_overhead"),
    ("table5", "benchmarks.modularity"),
    ("fig15", "benchmarks.elastic_sim"),
    ("themis", "benchmarks.preemption"),
    ("multi_shell", "benchmarks.multi_shell"),
    ("fig19-21", "benchmarks.single_tenant"),
    ("fig22", "benchmarks.multi_tenant"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    import importlib
    print("name,us_per_call,derived")
    failures = []
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for key, modname in BENCHES:
        if only and only not in (key, modname):
            continue
        try:
            mod = importlib.import_module(modname)
            mod.main()
        except Exception:  # noqa: BLE001 - report all benches
            traceback.print_exc()
            failures.append(key)
    if failures:
        print(f"# FAILURES: {failures}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
