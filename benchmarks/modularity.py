"""Paper Table 5 analogue: component-update (re-initialisation) latency.

FOS claim: swapping one component costs only that component's reload —
nothing else recompiles.  Measured: swap accelerator (re-place module),
swap shell (re-bind geometry + registry update), swap runtime (restart
daemon), each WITHOUT touching the other components; derived figure =
ratio vs the standard-flow analogue (recompile everything).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, timeit
from repro.core import Daemon, Shell, default_registry, uniform_shell
from repro.core.module import AccelModule
from repro.core import zoo


def main() -> list[str]:
    rows = []
    reg = default_registry()
    spec = uniform_shell("host1_s1", (1, 1), 1)
    shell = Shell(spec)

    # accelerator swap: place a *different* module into the slot
    m1 = AccelModule("mandel", zoo.build_mandelbrot, [1])
    m2 = AccelModule("sobel", zoo.build_sobel, [1])
    m1.place(shell.slots[0], 1)
    m2.place(shell.slots[0], 1)          # warm both programs
    t_acc = timeit(lambda: m1.place(shell.slots[0], 1), iters=3)
    rows.append(row("table5/accelerator_swap", t_acc * 1e6,
                    "re-place resident module"))

    # shell swap: new geometry bound, registry updated; modules untouched
    def swap_shell():
        new_spec = uniform_shell("host1_s1_v2", (1, 1), 1)
        reg.register_shell(new_spec)
        return Shell(new_spec)
    t_shell = timeit(swap_shell, iters=5)
    rows.append(row("table5/shell_swap", t_shell * 1e6,
                    "re-bind geometry"))

    # runtime swap: restart the daemon (state rebuilt from registry)
    def swap_runtime():
        d = Daemon(shell, reg)
        d.shutdown()
    t_rt = timeit(swap_runtime, iters=3)
    rows.append(row("table5/runtime_swap", t_rt * 1e6, "daemon restart"))

    # standard-flow analogue: a shell change forces recompiling everything
    def recompile_world():
        mm1 = AccelModule("mandel_r", zoo.build_mandelbrot, [1])
        mm2 = AccelModule("sobel_r", zoo.build_sobel, [1])
        mm1.place(shell.slots[0], 1)
        mm2.place(shell.slots[0], 1)
    t_world = timeit(recompile_world, warmup=0, iters=2)
    rows.append(row("table5/standard_flow_full_rebuild", t_world * 1e6,
                    f"modularity_gain={t_world / max(t_shell, 1e-9):.1f}x"))
    return rows


if __name__ == "__main__":
    main()
