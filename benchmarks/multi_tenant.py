"""Paper Fig. 22 analogue: two mutually-unaware tenants under dynamic
offload (mandelbrot ~ compute-bound, sobel ~ memory-bound).

Grid over exposed parallelism (n_mandel x n_sobel in 1..3), relative
latency vs the 1x1 scenario, via the calibrated simulator on a 3-slot
shell (the paper's Ultra-96).  Derived figure: improvement of the best
greedy configuration over 1x1 (paper reports 46%).
"""
from __future__ import annotations

from benchmarks.common import row
from repro.core import ImplAlt, ModuleDescriptor, PolicyConfig, Registry, \
    SimJob, simulate


MANDEL_FRAME_MS = 36.0          # compute-bound: total work per frame
SOBEL_FRAME_MS = 18.0           # memory-bound
OVERHEAD_MS = 1.5               # per-chunk fetch/writeback
MEM_PENALTY = 1.25              # sobel replication pollutes DRAM rows


def _registry(nm: int, ns: int) -> Registry:
    """Fixed work per frame split into n chunks (paper programming model):
    each chunk costs frame/n + per-chunk overhead; sobel chunks slow down
    when replicated (row pollution, paper 5.5.2)."""
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="mandelbrot", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, MANDEL_FRAME_MS / nm + OVERHEAD_MS),)))
    sobel_chunk = SOBEL_FRAME_MS / ns + OVERHEAD_MS
    if ns > 1:
        sobel_chunk *= MEM_PENALTY
    reg.register_module(ModuleDescriptor(
        name="sobel", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, sobel_chunk),)))
    return reg


def main() -> list[str]:
    rows = []
    lat: dict[tuple[int, int], float] = {}
    for nm in (1, 2, 3):
        for ns in (1, 2, 3):
            jobs = [SimJob(0.0, "mandel_user", "mandelbrot", nm),
                    SimJob(0.0, "sobel_user", "sobel", ns)]
            r = simulate(_registry(nm, ns), 3, jobs,
                         PolicyConfig(reconfig_penalty_ms=2.0))
            lat[(nm, ns)] = r.makespan
    base = lat[(1, 1)]
    for (nm, ns), t in sorted(lat.items()):
        rows.append(row(f"fig22/{nm}mandel_x_{ns}sobel", t * 1e3,
                        f"rel={t / base:.3f}"))
    best = min(lat.values())
    rows.append(row("fig22/best_vs_1x1", 0.0,
                    f"improvement={(1 - best / base) * 100:.1f}%"))
    return rows


if __name__ == "__main__":
    main()
