"""Shared benchmark helpers.  Every benchmark prints CSV rows:
    name,us_per_call,derived
where `derived` is a benchmark-specific figure of merit (speedup, ratio,
utilization, ...).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def row(name: str, us_per_call: float, derived) -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    return line


def write_bench(out: str, pr: int, bench: str, metrics: dict,
                gates: dict | None = None) -> None:
    """Write the standard `BENCH_<pr>.json` artifact.

    One schema across every benchmark so the perf trajectory stays
    machine-readable PR over PR:

        {"pr": N, "bench": "<name>",
         "metrics": {...measurements...},
         "gates": {...bounds and pass/fail...}}

    `out` falsy (CI smoke runs pass `--out ''`) writes nothing.
    """
    if not out:
        return
    payload = {"pr": pr, "bench": bench, "metrics": metrics,
               "gates": gates or {}}
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    print(f"wrote {out}", flush=True)


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def run_subprocess(code: str, device_count: int | None = None,
                   timeout: int = 1200) -> str:
    """Run python code in a clean subprocess (optionally with N fake host
    devices) and return stdout.  Benchmarks needing multiple devices use
    this so the parent keeps its 1-device view."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    if device_count:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={device_count}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"subprocess failed:\n{out.stderr[-4000:]}")
    return out.stdout
