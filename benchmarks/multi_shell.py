"""Multi-shell scale-out: locality-aware stealing vs static partitioning.

A two-shell fabric (2 slots each) under a skewed workload: a heavy tenant
pins a deep backlog of batch jobs to shell s0 (`affinity="s0"`), while s1
only receives a couple of short jobs and then goes idle.  Three policies
replay the identical trace:

  - **static**: `PolicyConfig(steal=False)` — per-shell partitioning; the
    idle shell cannot help, the backlogged shell bounds the makespan;
  - **steal**: idle s1 pulls pending chunks queued behind s0's backlog
    (paying the reconfiguration penalty through the normal cost model);
  - **steal+refine**: stealing plus online cost-model refinement
    (`refine_cost_model=True`) with a deliberately mis-estimated module,
    showing the EWMA-corrected estimates don't change correctness.

Acceptance: stealing must improve makespan by >= 1.2x over static
partitioning on the skewed trace (it approaches 2x as the skew deepens).
A second scenario reports the locality win: alternating two modules with
no affinity, locality-aware dispatch parks each module on its own shell
and avoids almost all reconfigurations vs load-only dispatch.

Heterogeneous section: a fast (speed 1.0) + slow (speed 0.25) two-shell
fabric replays one no-affinity trace twice — `speed_aware=True` (ECT
placement sees the true clocks) vs `speed_aware=False` (the scheduler
plans as if both shells ran at the reference clock; true service times
still apply).  Acceptance: speed-aware placement must win by >= 1.3x
makespan.  A steal-pricing row shows the speed-aware slow shell
stopping steals it cannot finish before the fast shell would anyway,
and a prohibitive per-pair `transfer_ms` suppressing stealing entirely
(enforced).

Checkpointed migration section: hi-prio arrivals evict mid-flight
batch chunks; with `PolicyConfig.ckpt` the victims keep their progress
(`SimResult.reclaimed_ms`) and an idle shell may *resume* a
checkpointed chunk cross-shell when restore + transfer + remaining
beats the victim draining it locally (enforced: checkpointing must not
discard more slot-time than the lossy baseline).
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.common import row, write_bench
from repro.core import Fabric, ImplAlt, ModuleDescriptor, PolicyConfig, \
    Registry, SimJob, simulate

SHELLS = {"s0": 2, "s1": 2}


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="short", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 6.0), ImplAlt("x2", 2, 3.5))))
    # mis-estimated: the scheduler believes 60 ms, the true time is 40 ms
    reg.register_module(ModuleDescriptor(
        name="skewed-est", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 60.0, meta={"true_chunk_ms": 40.0}),)))
    return reg


def skewed_trace(n_heavy: int, module: str = "batch") -> list[SimJob]:
    """Deep backlog pinned to s0; s1 sees two short jobs then idles."""
    jobs = [SimJob(2.0 * i, "heavy", module, 6, affinity="s0")
            for i in range(n_heavy)]
    jobs += [SimJob(0.0, "light", "short", 2, affinity="s1"),
             SimJob(5.0, "light", "short", 1, affinity="s1")]
    return jobs


def locality_trace(n_jobs: int) -> list[SimJob]:
    """Two modules arriving interleaved, no affinity: locality-aware
    dispatch should park each module on its own shell."""
    jobs = []
    for i in range(n_jobs):
        mod = "batch" if i % 2 == 0 else "short"
        jobs.append(SimJob(3.0 * i, f"t{i % 3}", mod, 2))
    return jobs


HETERO = {"fast": (2, 1.0), "slow": (2, 0.25)}


def hetero_trace(n_jobs: int) -> list[SimJob]:
    """No-affinity batch stream: placement alone decides which shell
    generation each job lands on."""
    return [SimJob(5.0 * i, f"t{i % 3}", "batch", 4)
            for i in range(n_jobs)]


def main(quick: bool = False, out: str = "") -> list[str]:
    """`out` names the BENCH_2.json artifact ('' disables, the
    programmatic default — benchmarks/run.py must not drop artifacts in
    the caller's cwd)."""
    reg = _registry()
    n_heavy = 3 if quick else 10
    rows = []

    # -- stealing vs static partitioning on the skewed trace ----------------
    res = {}
    policies = (
        ("static", PolicyConfig(steal=False)),
        ("steal", PolicyConfig(steal=True)),
        ("steal+refine", PolicyConfig(steal=True,
                                      refine_cost_model=True)))
    for name, pol in policies:
        module = "skewed-est" if name == "steal+refine" else "batch"
        fab = Fabric(SHELLS, reg, pol)
        r = simulate(reg, fab, skewed_trace(n_heavy, module), pol)
        res[name] = r
        per_shell = " ".join(
            f"{s}_util={d['utilization']:.3f}"
            for s, d in r.per_shell.items())
        extra = ""
        if pol.refine_cost_model:
            extra = (f" est_refined=60->"
                     f"{fab.cost.est_chunk_ms(module, 1):.1f}ms")
        rows.append(row(
            f"multi_shell/skew/{name}/makespan", r.makespan * 1e3,
            f"util={r.utilization:.3f} stolen={r.stolen_chunks} "
            f"reconfigs={r.reconfigurations} "
            f"discarded={r.discarded_ms:.0f}ms "
            f"reclaimed={r.reclaimed_ms:.0f}ms {per_shell}{extra}"))
    speedup = res["static"].makespan / max(res["steal"].makespan, 1e-9)
    rows.append(row(
        "multi_shell/skew/steal_vs_static", 0.0,
        f"makespan_speedup={speedup:.2f}x "
        f"(acceptance: >=1.2x) stolen={res['steal'].stolen_chunks}"))
    if speedup < 1.2:
        print(f"FAIL: stealing speedup {speedup:.2f}x < 1.2x",
              file=sys.stderr)
        sys.exit(1)

    # -- locality-aware dispatch vs load-only dispatch (stealing on in
    # both, so the comparison isolates residency-aware placement).  The
    # trace length is NOT shrunk in quick mode: below ~16 jobs the two
    # dispatch policies coincide and the row would carry no signal.
    n_jobs = 16
    loc = simulate(reg, SHELLS, locality_trace(n_jobs),
                   PolicyConfig(locality=True, steal=True))
    noloc = simulate(reg, SHELLS, locality_trace(n_jobs),
                     PolicyConfig(locality=False, steal=True))
    rows.append(row(
        "multi_shell/locality/reconfigs", float(loc.reconfigurations),
        f"locality={loc.reconfigurations} "
        f"load_only={noloc.reconfigurations} "
        f"makespan_ratio="
        f"{noloc.makespan / max(loc.makespan, 1e-9):.2f}x"))
    if loc.reconfigurations >= noloc.reconfigurations:
        print(f"FAIL: locality-aware dispatch did not reduce "
              f"reconfigurations ({loc.reconfigurations} vs "
              f"{noloc.reconfigurations})", file=sys.stderr)
        sys.exit(1)

    # -- heterogeneous fabric: speed-aware vs speed-blind placement ---------
    # stealing AND locality off so the rows isolate the dispatch
    # decision (locality would pin the whole stream to whichever shell
    # hosted the first job); the blind run schedules the identical
    # hardware, it just cannot see the clocks
    n_het = 6 if quick else 12
    het = {}
    for name, aware in (("aware", True), ("blind", False)):
        r = simulate(reg, Fabric(HETERO, reg,
                                 PolicyConfig(steal=False,
                                              locality=False,
                                              speed_aware=aware)),
                     hetero_trace(n_het))
        het[name] = r
        per_shell = " ".join(
            f"{s}_util={d['utilization']:.3f}"
            for s, d in r.per_shell.items())
        rows.append(row(
            f"multi_shell/hetero/{name}/makespan", r.makespan * 1e3,
            f"mean_lat={r.mean_latency:.0f}ms {per_shell}"))
    het_speedup = het["blind"].makespan / max(het["aware"].makespan,
                                              1e-9)
    rows.append(row(
        "multi_shell/hetero/aware_vs_blind", 0.0,
        f"makespan_speedup={het_speedup:.2f}x (acceptance: >=1.3x)"))
    if het_speedup < 1.3:
        print(f"FAIL: speed-aware placement speedup "
              f"{het_speedup:.2f}x < 1.3x", file=sys.stderr)
        sys.exit(1)

    # -- steal pricing: (a) speed — the slow shell stops stealing chunks
    # it would finish later than the fast shell clearing its own
    # backlog; (b) transfer — a per-pair payload-movement cost priced
    # high enough suppresses stealing entirely, without hurting the
    # makespan the victim achieves on its own
    fast_backlog = [SimJob(2.0 * i, "heavy", "batch", 6, affinity="fast")
                    for i in range(n_het)]
    st_aware = simulate(reg, Fabric(HETERO, reg,
                                    PolicyConfig(speed_aware=True)),
                        fast_backlog)
    st_blind = simulate(reg, Fabric(HETERO, reg,
                                    PolicyConfig(speed_aware=False)),
                        fast_backlog)
    st_priced = simulate(
        reg, Fabric(HETERO, reg, PolicyConfig(speed_aware=True),
                    transfer={"fast->slow": 1e6, "slow->fast": 1e6}),
        fast_backlog)
    rows.append(row(
        "multi_shell/hetero/steal_pricing", 0.0,
        f"aware_stolen={st_aware.stolen_chunks} "
        f"blind_stolen={st_blind.stolen_chunks} "
        f"transfer_priced_stolen={st_priced.stolen_chunks} "
        f"aware_makespan={st_aware.makespan:.0f}ms "
        f"blind_makespan={st_blind.makespan:.0f}ms "
        f"transfer_priced_makespan={st_priced.makespan:.0f}ms"))
    if st_priced.stolen_chunks != 0:
        print(f"FAIL: a prohibitive transfer cost did not suppress "
              f"stealing ({st_priced.stolen_chunks} chunks stolen)",
              file=sys.stderr)
        sys.exit(1)

    # -- checkpointed migration: hi-prio arrivals evict mid-flight batch
    # chunks on s0; with PolicyConfig.ckpt the victims keep their
    # progress and the idle s1 may *resume* one (restore + transfer +
    # remaining gated against the victim draining locally) instead of
    # the chunk re-running from zero.  Same trace with ckpt off is the
    # lossy baseline.  The arrivals land ~20 ms into each 40 ms chunk,
    # so every eviction has real progress at stake.
    burst = [SimJob(0.0, "heavy", "batch", 6, affinity="s0"),
             SimJob(0.0, "med", "batch", 3, affinity="s1")]
    burst += [SimJob(25.0 + 45.0 * i, "live", "short", 1, priority=4,
                     affinity="s0") for i in range(4)]
    ck = {}
    for name, on in (("off", False), ("on", True)):
        r = simulate(reg, SHELLS, burst,
                     PolicyConfig(preemptive=True, steal=True, ckpt=on,
                                  transfer_ms=1.0))
        ck[name] = r
        rows.append(row(
            f"multi_shell/ckpt_{name}/makespan", r.makespan * 1e3,
            f"preemptions={r.preemptions} stolen={r.stolen_chunks} "
            f"discarded={r.discarded_ms:.0f}ms "
            f"reclaimed={r.reclaimed_ms:.0f}ms "
            f"migrations={r.ckpt_migrations}"))
    rows.append(row(
        "multi_shell/ckpt_vs_lossy", 0.0,
        f"discarded={ck['off'].discarded_ms:.0f}->"
        f"{ck['on'].discarded_ms:.0f}ms "
        f"makespan={ck['off'].makespan:.0f}->{ck['on'].makespan:.0f}ms "
        f"migrations={ck['on'].ckpt_migrations}"))
    if ck["on"].discarded_ms > ck["off"].discarded_ms:
        print(f"FAIL: checkpointing discarded more slot-time than the "
              f"lossy baseline ({ck['on'].discarded_ms:.0f} vs "
              f"{ck['off'].discarded_ms:.0f} ms)", file=sys.stderr)
        sys.exit(1)

    # only reached with every gate satisfied (failures exited above)
    write_bench(out, 2, "multi_shell", metrics={
        "trace": {"n_heavy": n_heavy, "n_loc_jobs": n_jobs,
                  "n_hetero_jobs": n_het, "quick": quick},
        "skew": {"makespan_ms": {n: round(r.makespan, 3)
                                 for n, r in res.items()},
                 "stolen_chunks": res["steal"].stolen_chunks},
        "locality": {"reconfigs": loc.reconfigurations,
                     "load_only_reconfigs": noloc.reconfigurations},
        "hetero": {"makespan_ms": {n: round(r.makespan, 3)
                                   for n, r in het.items()},
                   "priced_stolen": st_priced.stolen_chunks},
        "ckpt": {"discarded_ms": {n: round(r.discarded_ms, 1)
                                  for n, r in ck.items()},
                 "reclaimed_ms": round(ck["on"].reclaimed_ms, 1),
                 "migrations": ck["on"].ckpt_migrations},
    }, gates={
        "steal_speedup_min": 1.2, "steal_speedup": round(speedup, 3),
        "hetero_speedup_min": 1.3,
        "hetero_speedup": round(het_speedup, 3),
        "locality_fewer_reconfigs": True,
        "priced_steal_suppressed": True,
        "ckpt_no_extra_discard": True,
        "pass": True,
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller skew/hetero traces for CI smoke")
    ap.add_argument("--out", default="BENCH_2.json",
                    help="result JSON path ('' disables)")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)
