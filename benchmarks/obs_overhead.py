"""Flight-recorder overhead gate (PR 9, repro.obs).

Measures the observability subsystem's cost on the sim_throughput
workload (24 heterogeneous shells, saturating mixed
preempt+steal+ckpt+adaptive trace) in four interleaved series:

- **base** — no recorder attached (the pre-PR product path);
- **ctrl** — no recorder either: a second detached series that serves
  as the A/A noise control for both gates;
- **off** — still no recorder: the detached hot path is a single
  ``if self.obs is not None`` test per hook, so off-vs-ctrl is the
  guard-branch cost.  Gate: <=1% of baseline run time;
- **on** — full tracing + counters + 5 ms gauge sampling attached.
  Gate: <=8%.

Why the control series: shared-machine noise on CI-class hosts dwarfs
a 1% bound — individual run times here swing 10-70% across contention
epochs, and an epoch can span a whole trial, so no raw off/base
comparison is trustworthy at any affordable sample size.  But when
the four series are interleaved (each iteration times all four back
to back in rotating order, GC disabled inside the timed region),
every series samples the same epochs, so *series-level medians are
correlated and their difference cancels the machine*: the gated
overhead is ``median(off_i/base_i) - median(ctrl_i/base_i)``, which
is zero-centered by construction for healthy code regardless of how
noisy the trial was.  A gate trips only when both

1. the control-subtracted median differential exceeds the bound by
   more than twice its own robust standard error (1.4826 x MAD /
   sqrt(n) — the allowance self-widens exactly in the trials where
   the noise is bad), and
2. the min-over-runs ratio ``min(off)/min(ctrl)`` exceeds the bound
   (timeit discipline: minima come from the least-contended run of
   each series, so a burst cannot fake a regression).

A real regression on the guarded path (e.g. a hook made
unconditional) shifts every run of one series and trips both
conditions together.  The run also asserts the acceptance
invariants: an attached recorder changes no scheduling output
(SimResult equality minus `metrics`), every timeline span pairs with
chunk_start/chunk_complete trace events, and the counter
conservation identities hold.

Writes `BENCH_9.json` (standard write_bench schema), including the
self-profiler's dirty-visit elision rate on this workload.
"""
from __future__ import annotations

import argparse
import dataclasses
import gc
import itertools
import math
import statistics
import sys
import time

from benchmarks.common import row, write_bench
from benchmarks.sim_throughput import (SPEEDS, _policy, _registry,
                                       mixed_trace, n_events)
from repro.core import Fabric, simulate
from repro.obs import FlightRecorder
from repro.obs import trace as tr

GATE_OFF = 0.01                # tracing-off overhead bound
GATE_ON = 0.08                 # full tracing+counters overhead bound
SAMPLE_MS = 5.0                # gauge-sampling interval for the on series


def run_once(n_shells: int, jobs, recorder=None):
    """One timed replay; returns (wall seconds, SimResult).

    Collects garbage before timing so one series' allocation debris
    does not bill a later series' runs."""
    reg = _registry()
    shells = {f"s{i:02d}": (4, SPEEDS[i % len(SPEEDS)])
              for i in range(n_shells)}
    fab = Fabric(shells, reg, _policy())
    if recorder is not None:
        recorder.attach(fab)
    gc.collect()
    gc.disable()            # collector pauses are the dominant noise
    try:
        t0 = time.perf_counter()
        res = simulate(reg, fab, jobs)
        dt = time.perf_counter() - t0
    finally:
        gc.enable()
    return dt, res


def _gate(diffs: list[float], min_ratio: float,
          bound: float) -> tuple[float, float, bool]:
    """Noise-robust verdict from control-subtracted differentials
    (see module docstring).  Returns (median overhead, allowance, ok):
    trips only when the median clears bound + 2 robust standard
    errors AND the burst-rejecting min-over-runs ratio clears the
    bound too."""
    ovh = statistics.median(diffs)
    mad = statistics.median(abs(d - ovh) for d in diffs)
    allow = bound + 2.0 * 1.4826 * mad / math.sqrt(len(diffs))
    return ovh, allow, not (ovh > allow and min_ratio - 1.0 > bound)


def _check_invariants(res_base, res_on, rec) -> None:
    """The acceptance assertions: recorder-on scheduling outputs are
    unchanged, spans pair with trace events, counters conserve."""
    d_on = dataclasses.asdict(res_on)
    d_base = dataclasses.asdict(res_base)
    d_on.pop("metrics")
    d_base.pop("metrics")
    assert d_on == d_base, \
        "attached recorder changed scheduling outputs"
    events = list(rec.tracer.events)
    starts = sum(1 for e in events if e.kind == tr.CHUNK_START)
    comps = sum(1 for e in events if e.kind == tr.CHUNK_COMPLETE)
    pres = sum(1 for e in events if e.kind == tr.PREEMPT)
    assert comps == len(res_on.timeline), \
        f"{comps} chunk_complete events vs {len(res_on.timeline)} spans"
    assert pres == len(res_on.preempted_spans)
    assert starts == comps + pres, (starts, comps, pres)
    c = res_on.metrics["counters"]
    assert c["steal_probes"] == c["steal_hits"] + c["steal_misses"]
    assert c["submitted"] == (c["admitted"] + c["degraded"]
                              + c["rejected"])
    # every restore consumes a record created at some eviction; the
    # recorder counts save *events* (the manager's own `saves` skips
    # re-recorded prior contexts, so it is not the conserved quantity)
    ck = res_on.metrics.get("ckpt", {})
    assert c["ckpt_saves"] >= ck.get("restores", 0)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller trace for CI smoke (gates still on)")
    ap.add_argument("--no-gate", action="store_true",
                    help="report only; skip the overhead acceptance exit")
    ap.add_argument("--out", default="BENCH_9.json",
                    help="result JSON path ('' disables)")
    args = ap.parse_args(argv)

    n_shells = 24
    n_jobs = 600 if args.quick else 1200
    iters = 7 if args.quick else 9
    jobs = mixed_trace(n_jobs, n_tenants=16, seed=7, gap_ms=1.0)

    run_once(n_shells, jobs)                     # interpreter warmup
    # each iteration times all four series back to back in rotating
    # order (striding the permutation list so consecutive iterations
    # do not share order prefixes); see the module docstring for why
    # the interleave + control subtraction is what makes a 1% bound
    # measurable at all on shared hardware
    modes = ("base", "ctrl", "off", "on")
    orders = list(itertools.permutations(modes))
    times: dict[str, list[float]] = {m: [] for m in modes}
    diffs: dict[str, list[float]] = {"off": [], "on": []}
    res_base = res_on = rec = None
    for i in range(iters):
        t_i: dict[str, float] = {}
        for mode in orders[(i * 7) % len(orders)]:
            if mode == "on":
                r = FlightRecorder(trace=True, max_events=1 << 20,
                                   sample_every_ms=SAMPLE_MS)
                t_i[mode], res_on = run_once(n_shells, jobs, recorder=r)
                rec = r
            else:
                t_i[mode], res_base = run_once(n_shells, jobs)
        for mode in modes:
            times[mode].append(t_i[mode])
        diffs["off"].append((t_i["off"] - t_i["ctrl"]) / t_i["base"])
        diffs["on"].append((t_i["on"] - t_i["ctrl"]) / t_i["base"])
    _check_invariants(res_base, res_on, rec)

    ev = n_events(res_base)
    t_min = {m: min(times[m]) for m in modes}
    eps = {m: ev / t_min[m] for m in modes}
    ovh_off, allow_off, ok_off = _gate(
        diffs["off"], t_min["off"] / t_min["ctrl"], GATE_OFF)
    ovh_on, allow_on, ok_on = _gate(
        diffs["on"], t_min["on"] / t_min["ctrl"], GATE_ON)
    prof = res_on.metrics["profile"]
    aa = statistics.median(times["ctrl"][i] / times["base"][i]
                           for i in range(iters)) - 1.0
    row("obs_overhead/baseline", t_min["base"] / ev * 1e6,
        f"events_per_sec={eps['base']:.0f} events={ev} "
        f"wall={t_min['base']:.2f}s aa_noise={aa:+.2%}")
    row("obs_overhead/off", t_min["off"] / ev * 1e6,
        f"events_per_sec={eps['off']:.0f} overhead={ovh_off * 100:+.2f}% "
        f"(bound <={GATE_OFF * 100:.0f}%, "
        f"noise allowance {allow_off * 100:.2f}%, "
        f"min_ratio={t_min['off'] / t_min['ctrl'] - 1:+.2%})")
    row("obs_overhead/on", t_min["on"] / ev * 1e6,
        f"events_per_sec={eps['on']:.0f} overhead={ovh_on * 100:+.2f}% "
        f"(bound <={GATE_ON * 100:.0f}%, "
        f"noise allowance {allow_on * 100:.2f}%, "
        f"min_ratio={t_min['on'] / t_min['ctrl'] - 1:+.2%}) "
        f"trace_events={len(rec.tracer.events)} "
        f"samples={len(res_on.metrics.get('samples', []))}")
    row("obs_overhead/self_profile", 0.0,
        f"elision_rate={prof['elision_rate']:.3f} "
        f"backlog_hit_rate={prof['backlog_hit_rate']:.3f} "
        f"steal_cache_hit_rate={prof['steal_cache_hit_rate']:.3f} "
        f"heap_compactions={prof['heap_compactions']}")

    ok = ok_off and ok_on
    write_bench(args.out, 9, "obs_overhead", metrics={
        "trace": {"n_shells": n_shells, "n_jobs": n_jobs,
                  "n_tenants": 16, "seed": 7, "gap_ms": 1.0,
                  "iters": iters, "sample_every_ms": SAMPLE_MS,
                  "quick": args.quick},
        "events": ev,
        "baseline": {"wall_s": round(t_min["base"], 4),
                     "events_per_sec": round(eps["base"], 1)},
        "off": {"wall_s": round(t_min["off"], 4),
                "events_per_sec": round(eps["off"], 1)},
        "on": {"wall_s": round(t_min["on"], 4),
               "events_per_sec": round(eps["on"], 1),
               "trace_events": len(rec.tracer.events),
               "dropped_events": rec.tracer.dropped,
               "samples": len(res_on.metrics.get("samples", []))},
        "identical_results": True,
        "spans_paired": True,
        "self_profile": {
            "elision_rate": round(prof["elision_rate"], 4),
            "backlog_hit_rate": round(prof["backlog_hit_rate"], 4),
            "steal_cache_hit_rate":
                round(prof["steal_cache_hit_rate"], 4),
            "heap_compactions": prof["heap_compactions"],
            "passes": prof["passes"]},
    }, gates={"off_overhead_max": GATE_OFF,
              "on_overhead_max": GATE_ON,
              "off_overhead": round(ovh_off, 4),
              "off_noise_allowance": round(allow_off, 4),
              "off_min_ratio": round(t_min["off"] / t_min["ctrl"], 4),
              "on_overhead": round(ovh_on, 4),
              "on_noise_allowance": round(allow_on, 4),
              "on_min_ratio": round(t_min["on"] / t_min["ctrl"], 4),
              "pass": ok})

    if not args.no_gate and not ok:
        print(f"FAIL: observability overhead off={ovh_off * 100:+.2f}% "
              f"(bound <={GATE_OFF * 100:.0f}% + noise allowance "
              f"{(allow_off - GATE_OFF) * 100:.2f}%) "
              f"on={ovh_on * 100:+.2f}% (bound <={GATE_ON * 100:.0f}% "
              f"+ {(allow_on - GATE_ON) * 100:.2f}%)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
