"""Paper Table 2 analogue: bus-virtualisation (layout adaptor) overhead.

Measures the per-call cost of the adaptor layer for: identity (interface
already matches — the "no adaptor instantiated" case), dtype cast, batch
pad, and cast+pad; plus bytes moved per conversion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro.core import bus


def main() -> list[str]:
    rows = []
    want = (jax.ShapeDtypeStruct((256, 256), jnp.float32),)
    cases = {
        "identity": np.zeros((256, 256), np.float32),
        "cast": np.zeros((256, 256), np.float64),
        "pad": np.zeros((200, 256), np.float32),
        "cast+pad": np.zeros((200, 200), np.float64),
    }
    for name, arr in cases.items():
        def call(a=arr):
            out, rep = bus.adapt_inputs((a,), want)
            jax.block_until_ready(out)
            return rep
        t = timeit(call, iters=10)
        rep = call()
        rows.append(row(f"table2/adaptor/{name}", t * 1e6,
                        f"bytes_moved={rep.bytes_moved}"))
    return rows


if __name__ == "__main__":
    main()
