"""Deterministic, shard-aware token data pipeline with background prefetch.

Sources:
  - SyntheticSource: seeded per (step, shard) -> reproducible across
    restarts and across different data-parallel layouts (elastic restore
    keeps the stream aligned because seeding is by *global* step).
  - MemmapSource: flat uint16/uint32 token file, strided deterministically.

The pipeline yields host numpy batches; the train driver device_puts them
with the batch sharding (so the pipeline works for any mesh).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: str | None = None
    prefetch: int = 2


class SyntheticSource:
    """Markov-ish synthetic tokens: deterministic f(seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))
        b, s = self.cfg.global_batch, self.cfg.seq_len
        # low-entropy structure so tiny models can actually learn
        base = rng.integers(0, self.cfg.vocab, (b, 1), dtype=np.int64)
        drift = rng.integers(0, 7, (b, s), dtype=np.int64)
        toks = (base + np.cumsum(drift, axis=1)) % self.cfg.vocab
        return toks.astype(np.int32)


class MemmapSource:
    def __init__(self, cfg: DataConfig):
        assert cfg.path is not None
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        self.n = len(self.tokens) - cfg.seq_len - 1

    def batch(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step]))
        b, s = self.cfg.global_batch, self.cfg.seq_len
        starts = rng.integers(0, self.n, (b,))
        out = np.stack([self.tokens[i:i + s] for i in starts])
        return out.astype(np.int32) % self.cfg.vocab


def make_source(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticSource(cfg)
    if cfg.source == "memmap":
        return MemmapSource(cfg)
    raise ValueError(cfg.source)


class Pipeline:
    """Background-prefetching iterator of {"tokens": [B, S]} batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.source = make_source(cfg)
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            batch = {"tokens": self.source.batch(step)}
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5)
