"""Oracle for flash-decode (re-exported from flash_attention.ref)."""
from repro.kernels.flash_attention.ref import decode_attention_ref  # noqa: F401
