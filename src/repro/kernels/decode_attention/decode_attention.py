"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Decode is HBM-bandwidth-bound (the whole cache is read once per token); the
kernel streams KV blocks through VMEM with a running online-softmax merge —
no [S] score vector ever round-trips to HBM.

  grid = (batch, q_heads, S/bk); kv-block dim sequential with VMEM scratch
  (acc, m, l).  GQA native via index_map head folding.  The valid cache
  length arrives as a scalar-prefetch argument; blocks entirely past
  `length` are skipped (saves bandwidth when the cache is partly filled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _decode_kernel(length_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_k: int, kv_blocks: int):
    ki = pl.program_id(2)
    length = length_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(ki * block_k < length)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                   # [1, hd]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # [1, bk]
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_bhd(q, k, v, length, *, scale: float,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False):
    """q: [B, Hq, hd]; k, v: [B, Hkv, S, hd]; length: scalar int32 (number
    of valid cache positions).  Returns [B, Hq, hd]."""
    b, hq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0 and sk % block_k == 0, (hq, hkv, sk, block_k)
    g = hq // hkv
    q = (q * scale)[:, :, None, :]                            # [B,Hq,1,hd]
    grid = (b, hq, sk // block_k)
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               kv_blocks=sk // block_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd),
                             lambda bi, hi, ki, length: (bi, hi, 0, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda bi, hi, ki, length: (bi, hi // g, ki, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda bi, hi, ki, length: (bi, hi // g, ki, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, 1, hd), lambda bi, hi, ki, length: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, hd), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
                pltpu.VMEM((1, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, hd), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32)[None], q, k, v)
    return out[:, :, 0, :]
