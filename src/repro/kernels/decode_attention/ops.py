"""Jit-ready wrapper for the flash-decode kernel.

Model-facing layout: q [B, Hq, hd], caches [B, S, Hkv, hd] (the layout the
decode cache uses for cheap dynamic_update_slice).  On real TPU the cache
would be kept [B, Hkv, S, hd] to avoid the transpose; see DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as knl


@functools.partial(jax.jit, static_argnames=("scale", "block_k",
                                             "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, scale: float,
                     block_k: int = 512, interpret: bool = False):
    """q: [B,Hq,hd]; caches [B,S,Hkv,hd]; length: valid prefix length.
    Returns [B,Hq,hd]."""
    sk = k_cache.shape[1]
    block_k = min(block_k, max(128, 1 << (sk - 1).bit_length()))
    pk = (-sk) % block_k
    kt = jnp.transpose(k_cache, (0, 2, 1, 3)).astype(q.dtype)
    vt = jnp.transpose(v_cache, (0, 2, 1, 3)).astype(q.dtype)
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    return knl.decode_attention_bhd(q, kt, vt, length, scale=scale,
                                    block_k=block_k, interpret=interpret)
