"""Pallas TPU flash-attention (causal, GQA-native) — forward kernel.

Blockwise online-softmax:
  grid = (batch, q_heads, Sq/bq, Sk/bk), kv-block dimension innermost and
  sequential ("arbitrary"); VMEM scratch carries the running (acc, m, l)
  across kv blocks.  GQA is native: the kv BlockSpec index_map folds the
  q-head onto its kv head (h // group) — no KV repeat materialises.
  Causal block skipping: kv blocks strictly above the diagonal are skipped
  via pl.when (the dominant win at long context).

VMEM working set per step: q(bq,hd) + k/v(bk,hd) + scores(bq,bk) + acc(bq,hd)
~= 128*128*4B * 5 ~ 0.4 MiB at the default 128/128 blocks — comfortably
inside the ~16 MiB VMEM with double buffering; MXU-aligned (128 multiples).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # causal skip: whole kv block above the diagonal contributes nothing
    needed = (not causal) or (k_start <= q_start + block_q - 1)
    run = (k_start <= q_start + block_q - 1) if causal else True

    @pl.when(jnp.bool_(run) if isinstance(run, bool) else run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)                  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bk]
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                                   # [bq, 1]
        l_prev = l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_cur
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         scale: float | None = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False):
    """q: [B, Hq, Sq, hd]; k, v: [B, Hkv, Sk, hd] -> [B, Hq, Sq, hd].

    Sq/Sk must be multiples of the block sizes (ops.py pads).
    """
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    scale = hd ** -0.5 if scale is None else scale
    grid = (b, hq, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_blocks=sk // block_k)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
