"""Jit-ready wrapper for the flash-attention kernel ([B,S,H,hd] layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as knl


@functools.partial(jax.jit,
                   static_argnames=("causal", "scale", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B,Sq,Hq,hd]; k,v: [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd].

    Pads sequence lengths up to block multiples (padded kv keys sit at
    causal-masked positions > every real query, padded q rows are sliced
    off).  Non-causal inputs are delegated to the reference path (the
    kernel is causal-only by design).
    """
    if not causal:
        from repro.kernels.flash_attention.ref import attention_ref
        return attention_ref(q, k, v, causal=False, scale=scale)
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    block_q = min(block_q, max(16, 1 << (sq - 1).bit_length()))
    block_k = min(block_k, max(16, 1 << (sk - 1).bit_length()))
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = knl.flash_attention_bhsd(qt, kt, vt, causal=True, scale=scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    out = out[:, :, :sq]
    return jnp.transpose(out, (0, 2, 1, 3))
