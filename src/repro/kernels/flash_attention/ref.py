"""Pure-jnp oracle for causal GQA attention ([B, S, H, hd] layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  scale: float | None = None):
    """q: [B,Sq,Hq,hd]; k,v: [B,Sk,Hkv,hd] -> [B,Sq,Hq,hd] (q.dtype)."""
    b, sq, hq, hd = q.shape
    hkv, sk = k.shape[2], k.shape[1]
    g = hq // hkv
    scale = hd ** -0.5 if scale is None else scale
    kr = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kr) * scale
    if causal:
        mask = jnp.arange(sk)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, length, *, scale: float):
    """q: [B,Hq,hd]; k,v: [B,S,Hkv,hd]; length: #valid -> [B,Hq,hd]."""
    b, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), kr) * scale
    s = jnp.where(jnp.arange(sk)[None, None, :] < length, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, vr).astype(q.dtype)
