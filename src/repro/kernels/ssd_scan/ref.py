"""Pure-jnp oracle for the Mamba2 SSD chunked scan.

Semantics (per batch b, head h; P = headdim, N = d_state):
    h_t = exp(dt_t * a_h) * h_{t-1} + dt_t * B_t (x) x_t     (outer product)
    y_t = C_t . h_t
with B_t, C_t shared across the heads of a group (G groups, G | H).

Chunked evaluation (chunk length Q):
    within-chunk quadratic term + cross-chunk state recurrence.
All accumulation in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _repeat_groups(t: jax.Array, n_heads: int) -> jax.Array:
    """[B, L, G, N] -> [B, L, H, N]."""
    g = t.shape[2]
    assert n_heads % g == 0
    return jnp.repeat(t, n_heads // g, axis=2)


def ssd_ref(x, dt, a, b, c, chunk: int = 128, initial_state=None):
    """x: [B,L,H,P]; dt: [B,L,H] (post-softplus); a: [H] (negative);
    b, c: [B,L,G,N].  Returns (y [B,L,H,P] f32, final_state [B,H,P,N] f32).
    """
    bsz, seqlen, n_heads, p = x.shape
    n = b.shape[-1]
    assert seqlen % chunk == 0, (seqlen, chunk)
    nc, q = seqlen // chunk, chunk

    xf = x.astype(jnp.float32).reshape(bsz, nc, q, n_heads, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, q, n_heads)
    bh = _repeat_groups(b.astype(jnp.float32), n_heads).reshape(
        bsz, nc, q, n_heads, n)
    ch = _repeat_groups(c.astype(jnp.float32), n_heads).reshape(
        bsz, nc, q, n_heads, n)

    adt = dtf * a.astype(jnp.float32)[None, None, None, :]      # [B,NC,Q,H]
    cum = jnp.cumsum(adt, axis=2)                               # inclusive
    # within-chunk decay matrix  L[q,k] = exp(cum_q - cum_k),  k <= q
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # [B,NC,Q,K,H]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    lmat = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
    # diagonal (within-chunk) output
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", ch, bh) * lmat
    scores = scores * dtf[:, :, None, :, :]                     # weight by dt_k
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xf)
    # per-chunk end states:  sum_k exp(cum_Q - cum_k) dt_k B_k (x) x_k
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)                # [B,NC,Q,H]
    s_c = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn", decay_end * dtf, bh, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # [B,NC,H]

    # cross-chunk first-order recurrence via associative_scan (log-depth,
    # loop-free: preferred on TPU and exactly counted by HLO cost analysis)
    def combine(lhs, rhs):
        dl, sl = lhs
        dr, sr = rhs
        return dl * dr, sl * dr[..., None, None] + sr

    decays, states = jax.lax.associative_scan(
        combine, (chunk_decay, s_c), axis=1)                    # inclusive
    if initial_state is not None:
        init = initial_state.astype(jnp.float32)
        states = states + decays[..., None, None] * init[:, None]
    else:
        init = jnp.zeros((bsz, n_heads, p, n), jnp.float32)
    final = states[:, -1]
    s_prevs = jnp.concatenate(
        [init[:, None], states[:, :-1]], axis=1)                # [B,NC,H,P,N]
    # cross-chunk contribution:  C_q . (exp(cum_q) S_prev)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", ch, s_prevs, jnp.exp(cum))
    y = (y_diag + y_off).reshape(bsz, seqlen, n_heads, p)
    return y, final
