"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

grid = (batch, heads, n_chunks); the chunk dimension is sequential
("arbitrary") and the [P, N] SSD state lives in VMEM scratch across chunks —
the inter-chunk recurrence never round-trips HBM (the XLA path materialises
per-chunk states).  Within a chunk everything is quadratic in the chunk
length Q (default 128: MXU-aligned) and runs out of VMEM:

  working set ~ x(Q,P) + b,c(Q,N) + scores(Q,Q) + state(P,N)
  ~ 128*128*4B * 5 ~ 0.4 MiB.

B/C are group-shared across heads (G | H) via index_map head folding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)                       # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)                     # [1, Q]
    a = a_ref[0]                                              # scalar
    b = b_ref[0, 0].astype(jnp.float32)                      # [Q, N]
    c = c_ref[0, 0].astype(jnp.float32)                      # [Q, N]

    adt = dt[0] * a                                           # [Q]
    cum = jnp.cumsum(adt)                                     # [Q]
    # within-chunk decay L[q, k] = exp(cum_q - cum_k) for k <= q
    diff = cum[:, None] - cum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(ki <= qi, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * lmat            # [Q, K]
    scores = scores * dt[0][None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # cross-chunk: y += exp(cum_q) * C_q . S_prev
    state = state_ref[...]                                    # [N, P]
    y_off = jax.lax.dot_general(
        c, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]
    y_ref[0, 0] = (y + y_off).astype(y_ref.dtype)
    # state update: S = exp(cum_Q) S + sum_k exp(cum_Q - cum_k) dt_k B_k x_k
    w = jnp.exp(cum[-1] - cum) * dt[0]                        # [Q]
    s_new = jax.lax.dot_general(
        b * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                   # [N, P]
    state_ref[...] = state * jnp.exp(cum[-1]) + s_new

    @pl.when(ci == n_chunks - 1)
    def _finish():
        state_out_ref[0, 0] = state_ref[...]


def ssd_pallas(x, dt, a, b, c, *, chunk: int = 128, initial_state=None,
               interpret: bool = False):
    """x: [B,L,H,P]; dt: [B,L,H]; a: [H]; b,c: [B,L,G,N].
    Returns (y [B,L,H,P] f32, final_state [B,H,P,N] f32).

    Matches repro.kernels.ssd_scan.ref.ssd_ref.  initial_state is folded in
    afterwards via the same decay algebra (kernels start from zero state).
    """
    bsz, seqlen, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert seqlen % chunk == 0
    nc = seqlen // chunk
    rep = h // g

    xt = jnp.transpose(x, (0, 2, 1, 3))                       # [B,H,L,P]
    dtt = jnp.transpose(dt, (0, 2, 1))[:, :, None, :]         # [B,H,1,L]
    bt = jnp.transpose(b, (0, 2, 1, 3))                       # [B,G,L,N]
    ct = jnp.transpose(c, (0, 2, 1, 3))

    grid = (bsz, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, 0, ci)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, hi // rep, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, hi // rep, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, seqlen, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xt, dtt, a.astype(jnp.float32), bt, ct)

    y = jnp.transpose(y, (0, 2, 1, 3))                        # [B,L,H,P]
    state = jnp.transpose(state, (0, 1, 3, 2))                # [B,H,P,N]
    if initial_state is not None:
        # linearity: contribution of S0 decays by exp(sum a dt) cumulatively
        adt = dt.astype(jnp.float32) * a.astype(jnp.float32)[None, None, :]
        cum = jnp.cumsum(adt, axis=1)                         # [B,L,H]
        s0 = initial_state.astype(jnp.float32)                # [B,H,P,N]
        rep_ax = h // g
        ch = jnp.repeat(c.astype(jnp.float32), rep_ax, axis=2)  # [B,L,H,N]
        y_init = jnp.einsum("blhn,bhpn,blh->blhp", ch, s0, jnp.exp(cum))
        y = y + y_init
        state = state + s0 * jnp.exp(cum[:, -1])[..., None, None]
    return y, state
