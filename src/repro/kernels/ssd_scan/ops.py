"""Jit-ready entry point for the SSD chunked scan.

impl:
  "xla"              - pure-jnp chunked algorithm (ref), XLA-fused
  "pallas"           - Pallas TPU kernel
  "pallas_interpret" - Pallas kernel in interpret mode (CPU-validatable)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ref as ssd_ref_mod


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(x, dt, a, b, c, *, chunk: int = 128, impl: str = "xla",
        initial_state=None):
    """See ssd_scan.ref.ssd_ref for shapes. Returns (y, final_state)."""
    seqlen = x.shape[1]
    pad = (-seqlen) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))  # dt=0 => identity step
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if impl == "xla":
        y, final = ssd_ref_mod.ssd_ref(x, dt, a, b, c, chunk=chunk,
                                       initial_state=initial_state)
    elif impl in ("pallas", "pallas_interpret"):
        from repro.kernels.ssd_scan import ssd_scan as knl
        y, final = knl.ssd_pallas(x, dt, a, b, c, chunk=chunk,
                                  initial_state=initial_state,
                                  interpret=(impl == "pallas_interpret"))
    else:
        raise ValueError(f"unknown ssd impl {impl!r}")
    if pad:
        y = y[:, :seqlen]
    return y, final
