"""repro: FOS-on-JAX reproduction package.

Importing the package backfills a few newer-jax APIs that the codebase
targets but the container's pinned jax predates.  Each backfill delegates
to the stable equivalent and is skipped when the real API exists:

  - jax.tree.flatten_with_path / map_with_path  (jax.tree_util.*)
  - jax.set_mesh          (context form only; Mesh is a context manager)
  - jax.shard_map         (jax.experimental.shard_map; check_vma->check_rep)
  - pallas tpu CompilerParams                   (TPUCompilerParams)
"""
import jax as _jax
import jax.tree_util as _tu

if not hasattr(_jax.tree, "flatten_with_path"):
    _jax.tree.flatten_with_path = _tu.tree_flatten_with_path
if not hasattr(_jax.tree, "map_with_path"):
    _jax.tree.map_with_path = _tu.tree_map_with_path

if not hasattr(_jax, "set_mesh"):
    # every call site uses `with jax.set_mesh(mesh): ...`; on older jax the
    # Mesh object itself is the context manager that sets the ambient mesh
    _jax.set_mesh = lambda mesh: mesh

if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, mesh=None, in_specs=None, out_specs=None,
                          check_vma=True, **kwargs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)

    _jax.shard_map = _compat_shard_map

try:
    import jax.experimental.pallas.tpu as _pltpu
    if not hasattr(_pltpu, "CompilerParams"):
        _pltpu.CompilerParams = _pltpu.TPUCompilerParams
except ImportError:  # pallas optional on some backends
    pass
