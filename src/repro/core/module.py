"""AccelModule: an AOT-compiled program + weights, placeable into slots.

FOS mapping:
  - compile against a slot *interface* (shape + axes + abstract inputs), in
    isolation from the shell instance -> decoupled compilation;
  - placement into a congruent slot re-lowers against that slot's devices
    with the XLA compilation cache warm -> relocation (BitMan analogue);
  - weight transfer to the slot's devices = partial reconfiguration; the
    scheduler skips it when the module is already resident (paper 4.4.3).

A ModuleBuilder (referenced by the registry descriptor's entrypoint) returns
a ModuleProgram describing fn / abstract inputs / shardings / weights.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.core.shell import Slot


@dataclasses.dataclass
class ModuleProgram:
    """What a builder returns for a given footprint."""
    fn: Callable                         # (weights, *chunk_args) -> outputs
    abstract_weights: Any                # pytree of ShapeDtypeStruct
    abstract_inputs: tuple               # chunk args, ShapeDtypeStructs
    weight_pspecs: Any                   # PartitionSpec pytree (or None)
    input_pspecs: tuple                  # PartitionSpec pytrees
    output_pspecs: Any = None
    init_weights: Callable | None = None  # key -> concrete weights (host)

    def signature(self) -> dict:
        def leaf(s):
            return {"shape": list(s.shape), "dtype": str(s.dtype)}
        return {
            "inputs": jax.tree.map(leaf, list(self.abstract_inputs)),
            "weights": jax.tree.map(leaf, self.abstract_weights),
        }


@dataclasses.dataclass
class Placement:
    """A module implementation resident in a slot."""
    module: "AccelModule"
    footprint: int
    slot: Slot
    executable: Any
    weights_on_slot: Any
    load_time_s: float
    compile_time_s: float
    cache_hit: bool


class AccelModule:
    """A named accelerator with implementation alternatives."""

    def __init__(self, name: str, builder: Callable, footprints: list[int],
                 weights_key: int = 0):
        self.name = name
        self.builder = builder
        self.footprints = list(footprints)
        self._programs: dict[tuple, ModuleProgram] = {}
        self._host_weights: dict[int, Any] = {}
        self._compile_count = 0
        self._compile_keys: set[tuple] = set()
        self.weights_key = weights_key

    # -- decoupled compilation -------------------------------------------------

    def program(self, slot: Slot, footprint: int) -> ModuleProgram:
        key = (slot.congruence_key, footprint)
        if key not in self._programs:
            self._programs[key] = self.builder(slot.mesh, footprint)
        return self._programs[key]

    def host_weights(self, footprint: int):
        if footprint not in self._host_weights:
            prog = next(iter(self._programs.values()), None)
            assert prog is not None, "compile before requesting weights"
            if prog.init_weights is None:
                self._host_weights[footprint] = None
            else:
                self._host_weights[footprint] = prog.init_weights(
                    jax.random.PRNGKey(self.weights_key))
        return self._host_weights[footprint]

    def place(self, slot: Slot, footprint: int) -> Placement:
        """Compile (cache-mediated) + load weights onto the slot."""
        from jax.sharding import NamedSharding

        prog = self.program(slot, footprint)
        mesh = slot.mesh
        in_sh = tuple(
            jax.tree.map(lambda p: NamedSharding(mesh, p), ps)
            for ps in prog.input_pspecs)
        w_sh = (jax.tree.map(lambda p: NamedSharding(mesh, p),
                             prog.weight_pspecs)
                if prog.weight_pspecs is not None else None)
        t0 = time.perf_counter()
        args = (prog.abstract_weights, *prog.abstract_inputs)
        shardings = (w_sh, *in_sh) if w_sh is not None else (None, *in_sh)
        jitted = jax.jit(prog.fn, in_shardings=shardings)
        lowered = jitted.lower(*args)
        executable = lowered.compile()
        t1 = time.perf_counter()
        # congruence-class cache bookkeeping: a repeat compile of the same
        # (program, congruence) is a relocation, not a fresh compile
        ckey = (slot.congruence_key, footprint)
        cache_hit = ckey in self._compile_keys
        self._compile_keys.add(ckey)
        self._compile_count += 1
        # weight transfer = partial reconfiguration
        hw = self.host_weights(footprint)
        t2 = time.perf_counter()
        if hw is not None and w_sh is not None:
            w_dev = jax.device_put(hw, w_sh)
            jax.block_until_ready(w_dev)
        else:
            w_dev = None
        t3 = time.perf_counter()
        return Placement(self, footprint, slot, executable, w_dev,
                         load_time_s=t3 - t2, compile_time_s=t1 - t0,
                         cache_hit=cache_hit)


def run_placement(placement: Placement, *chunk_args):
    """Generic driver: invoke a resident module on concrete inputs."""
    from jax.sharding import NamedSharding

    prog = placement.module.program(placement.slot, placement.footprint)
    mesh = placement.slot.mesh
    args = []
    for a, ps in zip(chunk_args, prog.input_pspecs):
        sh = jax.tree.map(lambda p: NamedSharding(mesh, p), ps)
        args.append(jax.device_put(a, sh))
    if placement.weights_on_slot is not None:
        out = placement.executable(placement.weights_on_slot, *args)
    else:
        out = placement.executable(None, *args)
    return jax.block_until_ready(out)
