"""Online arrival-rate estimation for predictive reservation.

PR 4's steal-aware admission (`PolicyConfig.reserve_slots`) holds back
the last N slots of every shell for the interactive class — but N is a
static knob the operator must guess per trace, and the right value
drifts with the interactive arrival rate: too small and bursts queue
behind batch chunks, too large and reserved capacity idles.  THEMIS
(Karabulut et al., 2024) makes the same point for fair multi-tenant FPGA
scheduling — arbitration parameters must track the observed workload,
not a config file — and Mandebi Mbongue et al. (2020) motivate why
cloud multi-tenancy cannot assume a known tenant mix.

`ArrivalEstimator` is the feedback loop's sensor: an EWMA of
inter-arrival times, expected service and footprint **per priority
class**, observed once per job at admission (`Fabric.submit`; a bare
`SchedulerState` observes its own direct submits).  Stolen sub-request
re-submits are placement moves, not arrivals, and are never observed.

`demand_slots` turns the per-class rates into a Little's-law
concurrency estimate.  The reservation exists to cover interactive
demand over the window during which capacity cannot be created on
demand: without a free slot, an arrival waits for the resident batch
chunk to drain, then pays reconfiguration, then its own service.  So
for every class at or above the reservation priority,

    demand += rate [1/ms]
              x ((blocking_ms + service_ms) / speed + overhead_ms)
              x footprint

where `blocking_ms` is the largest expected chunk time among the
*non*-interactive classes (the capacity-creation latency on a
saturated shell; 0 when no batch work has been observed, leaving only
the burst's own service + reconfiguration in the horizon) and
`overhead_ms` is the caller's reconfiguration penalty.  The scheduler rounds and clamps the
sum to `[0, PolicyConfig.reserve_slots_max]` every scheduling pass
(`SchedulerState.effective_reserve`), replacing the static count when
`PolicyConfig.reserve_mode == "adaptive"`.

Staleness: a rate estimated from an EWMA alone would predict a burst
forever after the burst ends.  Queries therefore degrade the rate once
the gap since the class's last arrival grows well past its EWMA
inter-arrival (`STALE_FACTOR`): the effective inter-arrival is
`max(ewma, gap / STALE_FACTOR)`, so a class that stops arriving decays
to rate 0 — and a shell's adaptive reservation back to 0 — within a
handful of expected inter-arrivals, while ordinary exponential gaps
inside an active stream do not flap the reservation.
"""
from __future__ import annotations

import dataclasses

# a class's rate starts degrading once the gap since its last arrival
# exceeds STALE_FACTOR expected inter-arrivals: large enough that the
# long tail of an exponential arrival process (P[gap > 6*mean] ~ 0.25%)
# practically never flaps an active stream's reservation mid-gap, small
# enough that a stream that stops frees the reserved capacity within a
# few expected inter-arrivals
STALE_FACTOR = 6.0

# schedlint memo contract (analysis/memo.py): the demand memo is keyed
# on the query instant and the observation version (plus the argument
# tuple), so it may read the whole per-class EWMA surface and the clock
# but nothing of any shell's scheduling state.
MEMO_CONTRACTS = (
    {"name": "demand_slots",
     "func": "ArrivalEstimator.demand_slots",
     "cache": "_demand",
     "key": ("arrivals", "now", "args"),
     "folded": {}},
)


@dataclasses.dataclass
class ClassStats:
    """Per-priority-class EWMA state (one arrival seen at minimum)."""
    last_t: float                   # most recent arrival (ms)
    ia_ms: float | None = None      # EWMA inter-arrival; None until 2nd
    service_ms: float = 0.0         # EWMA per-chunk service estimate
    footprint: float = 1.0          # EWMA slots per placement
    n: int = 1                      # arrivals observed


class ArrivalEstimator:
    """EWMA arrival model per priority class, shared fabric-wide.

    `observe` is called once per admitted job; `demand_slots` is the
    predictive-reservation query.  All times are scheduler milliseconds
    (virtual in the simulator, `perf_counter * 1e3` in the daemon).
    """

    def __init__(self, alpha: float = 0.3):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"arrival_alpha must be in (0, 1], "
                             f"got {alpha}")
        self.alpha = float(alpha)
        self._classes: dict[int, ClassStats] = {}
        # demand_slots memo: the estimate is queried once per shell per
        # event (reservation sampling, dispatch ECT, steal sizing) but
        # only moves when the clock or an observation does
        self._version = 0
        self._demand_at: tuple[float, int] | None = None
        self._demand: dict[tuple[int, float, float, int], float] = {}

    def observe(self, priority: int, now: float,
                service_ms: float = 0.0, footprint: int = 1) -> None:
        """Record one arrival of `priority` class at `now`.

        `service_ms` is the cost model's speed-normalised per-chunk
        estimate for the submitted module at its smallest footprint —
        the reservation predicts slot *occupancy*, so the estimate
        rides along with the arrival clock.
        """
        self._version += 1
        c = self._classes.get(priority)
        if c is None:
            self._classes[priority] = ClassStats(
                last_t=now, service_ms=float(service_ms),
                footprint=float(footprint))
            return
        a = self.alpha
        dt = max(0.0, now - c.last_t)
        c.ia_ms = dt if c.ia_ms is None else a * dt + (1.0 - a) * c.ia_ms
        c.last_t = max(c.last_t, now)
        c.service_ms = a * service_ms + (1.0 - a) * c.service_ms
        c.footprint = a * footprint + (1.0 - a) * c.footprint
        c.n += 1

    # -- queries --------------------------------------------------------------

    def interarrival_ms(self, priority: int) -> float | None:
        """EWMA inter-arrival of one class (None before two arrivals)."""
        c = self._classes.get(priority)
        return None if c is None else c.ia_ms

    def rate_per_ms(self, priority: int, now: float) -> float:
        """Staleness-aware arrival rate of one class (0.0 when unknown)."""
        c = self._classes.get(priority)
        if c is None or c.ia_ms is None:
            return 0.0
        gap = max(0.0, now - c.last_t)
        ia = max(c.ia_ms, gap / STALE_FACTOR, 1e-6)
        return 1.0 / ia

    def blocking_ms(self, min_priority: int) -> float:
        """Largest expected chunk time among classes *below*
        `min_priority` — how long an interactive arrival would wait for
        a saturated shell to free a slot (0.0 when no batch work has
        been observed: only the burst's own service + overhead remain
        in the demand horizon)."""
        return max((c.service_ms for p, c in self._classes.items()
                    if p < min_priority), default=0.0)

    def demand_slots(self, min_priority: int, now: float,
                     overhead_ms: float = 0.0,
                     speed: float = 1.0, min_obs: int = 0) -> float:
        """Little's-law slot concurrency of classes >= `min_priority`:
        sum of rate x ((blocking + service) / speed + overhead) x
        footprint — each predicted arrival occupies provisioned
        capacity for the full window it would otherwise wait through
        (batch residual, then reconfiguration, then its own service).
        The caller passes the shell's reconfiguration penalty as
        `overhead_ms` and its decision speed.

        `min_obs` excludes classes with fewer arrivals: an EWMA seeded
        by one back-to-back pair (wall-clock submits land microseconds
        apart) reads as an absurd sustained rate, and callers whose
        query treats the result as steady-state load (the admission
        controller's utilisation check) need a few inter-arrival
        samples of evidence first.  The reservation path keeps the
        default 0 — over-reserving for one burst is self-correcting,
        turning away tenants is not.

        Memoized per (now, observation version): one computation serves
        every same-instant query (per-shell reservation sampling,
        dispatch ECT, steal sizing), returning the identical floats."""
        if self._demand_at != (now, self._version):
            self._demand_at = (now, self._version)
            self._demand = {}
        key = (min_priority, overhead_ms, speed, min_obs)
        hit = self._demand.get(key)
        if hit is not None:
            return hit
        blocking = self.blocking_ms(min_priority)
        total = 0.0
        for p, c in self._classes.items():
            if p < min_priority or c.n < min_obs:
                continue
            rate = self.rate_per_ms(p, now)
            if rate <= 0.0:
                continue
            total += rate * ((blocking + c.service_ms) / speed
                             + overhead_ms) * c.footprint
        self._demand[key] = total
        return total
