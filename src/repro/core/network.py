"""Link-level fabric interconnect: latency/bandwidth/queuing per link.

The scalar `PolicyConfig.transfer_ms` model prices every cross-shell
move as a constant, so ten thieves hammering one victim see the same
per-chunk cost as one.  `FabricNetwork` replaces it with the
FOS/NoC-style picture (Mbongue et al.: multi-tenant virtual regions
contending on a shared interconnect): shells attach to *ports* on a
switch topology, each directed link carries a fixed `latency_ms` plus
`bw_ms` (milliseconds per unit payload), and links have bounded output
buffers — concurrent transfers on a shared link serialize and queue
rather than overlapping for free.

Two operating modes, one API:

- **uniform** (the compatibility shim): no links at all — a per-pair
  dict plus a fabric-wide default, byte-for-byte the old
  `Fabric._transfer_ms` lookup.  `active` is False, `version` never
  moves, and the whole golden corpus reproduces unchanged.
- **links** (`crossbar` / `from_topology`): routes are precomputed by
  deterministic BFS over the switch graph; `est_transfer_ms` walks the
  route store-and-forward, charging queue wait against each link's
  `busy_until` horizon, and returns `inf` while any link's bounded
  buffer is full (the steal gate's back-off signal).  `reserve`
  realizes a transfer as timed link occupancy; `advance(now)` releases
  expired occupancy (the simulator drives it from heap events, the
  daemon from wall clock) and bumps `version` so the incremental
  scheduler re-dirties shells whose steal economics just changed.

Estimates and realized costs share one code path (`_walk`), so the
estimate is exact for the transfer that reserves immediately after
estimating — later reservations only push costs *up*, which is the
conservative direction for the steal gate.

schedlint: this is a sim module — no ambient time, no randomness; all
clocks are injected `now` parameters.
"""
from __future__ import annotations


class Link:
    """One directed edge (port->switch, switch->switch, or switch->port).

    `busy_until` is the serialization horizon: a new transfer starts no
    earlier than the previous one finished (store-and-forward, one
    in-flight frame per link — the FireSim-style bounded channel).
    `inflight` counts reserved-but-unreleased transfers occupying the
    bounded output buffer (`buffer` deep); estimates return `inf` while
    it is full.  `busy_ms`/`transfers`/`max_queue` are stats only.
    """

    __slots__ = ("src", "dst", "latency_ms", "bw_ms", "buffer",
                 "busy_until", "inflight", "busy_ms", "transfers",
                 "max_queue")

    def __init__(self, src: str, dst: str, latency_ms: float,
                 bw_ms: float, buffer: int):
        self.src = src
        self.dst = dst
        self.latency_ms = latency_ms
        self.bw_ms = bw_ms
        self.buffer = buffer
        self.busy_until = 0.0
        self.inflight = 0
        self.busy_ms = 0.0
        self.transfers = 0
        self.max_queue = 0

    @property
    def name(self) -> str:
        return f"{self.src}->{self.dst}"


class Transfer:
    """A realized (reserved) transfer: the receipt `reserve` returns.

    `total_ms` is what the mover pays end to end (`t_done - t_start`);
    `wait_ms` is the queueing share of it — time spent blocked behind
    earlier transfers before the first link even accepted the payload.
    """

    __slots__ = ("src", "dst", "payload", "t_start", "wait_ms",
                 "total_ms", "t_done", "route")

    def __init__(self, src, dst, payload, t_start, wait_ms, total_ms,
                 route):
        self.src = src
        self.dst = dst
        self.payload = payload
        self.t_start = t_start
        self.wait_ms = wait_ms
        self.total_ms = total_ms
        self.t_done = t_start + total_ms
        self.route = route


def _check_link_params(where: str, latency_ms, bw_ms, buffer) -> None:
    if not isinstance(latency_ms, (int, float)) or latency_ms < 0:
        raise ValueError(f"{where}: latency_ms must be a number >= 0, "
                         f"got {latency_ms!r}")
    if not isinstance(bw_ms, (int, float)) or bw_ms < 0:
        raise ValueError(f"{where}: bw_ms must be a number >= 0 "
                         f"(milliseconds per unit payload), got {bw_ms!r}")
    if not isinstance(buffer, int) or isinstance(buffer, bool) \
            or buffer < 1:
        raise ValueError(f"{where}: buffer must be an int >= 1, "
                         f"got {buffer!r}")


def validate_topology(topo: dict, shells) -> None:
    """Validate a topology JSON dict against a shell-name collection.

    Raises ValueError naming the offending key/pair — descriptor loads
    fail at `from_json` time, not later at steal time.  Constructing a
    `FabricNetwork.from_topology` performs the same checks; this is the
    load-time entry point `FabricDescriptor` uses.
    """
    FabricNetwork.from_topology(topo, shells)


class FabricNetwork:
    """Deterministic link-level interconnect model (or its uniform shim).

    Construct via `uniform` (scalar compatibility), `crossbar` (every
    shell on one switch), or `from_topology` (JSON multi-switch).
    """

    # -- construction --------------------------------------------------------

    def __init__(self):
        # built by the classmethods; direct construction is internal
        self._mode = "uniform"
        self._default = 0.0
        self._pairs: dict[tuple[str, str], float] = {}
        self._links: dict[tuple[str, str], Link] = {}
        self._routes: dict[tuple[str, str], tuple] = {}
        self._ports: dict[str, str] = {}
        self._active: list[Transfer] = []     # reserved, not yet released
        self._pending: list[Transfer] = []    # reserved since last drain
        self.version = 0                      # bumps on reserve/release

    @classmethod
    def uniform(cls, shells, default_ms: float = 0.0,
                pairs: dict | None = None) -> "FabricNetwork":
        """Degenerate topology: the old scalar model, byte-identical.

        `pairs` maps `(victim, thief)` tuples to per-pair costs (already
        parsed by `parse_transfer_pair`); everything else pays
        `default_ms`.  No links, no state, `version` never moves.
        """
        net = cls()
        net._default = default_ms
        net._pairs = dict(pairs or {})
        return net

    @classmethod
    def crossbar(cls, shells, latency_ms: float = 0.1,
                 bw_ms: float = 0.0, buffer: int = 4) -> "FabricNetwork":
        """Every shell on one switch — the default link topology."""
        return cls.from_topology({
            "switches": ["xbar"],
            "ports": {s: "xbar" for s in shells},
            "default_link": {"latency_ms": latency_ms, "bw_ms": bw_ms,
                             "buffer": buffer},
        }, shells)

    @classmethod
    def from_topology(cls, topo: dict, shells) -> "FabricNetwork":
        """Build (and fully validate) a link topology from JSON.

        Schema::

            {"switches": ["sw0", "sw1"],
             "ports":    {"<shell-or-port>": "<switch>", ...},
             "default_link": {"latency_ms": f, "bw_ms": f, "buffer": i},
             "links": [{"src": n, "dst": n, "latency_ms": f, "bw_ms": f,
                        "buffer": i, "duplex": true}, ...]}

        Every shell must have a port; extra port names (e.g. "ingress",
        consulted by ECT dispatch) are allowed.  A `links` entry whose
        endpoints are a port and its switch overrides that attachment's
        default parameters; switch-to-switch links exist only if listed
        (duplex by default).  Unreachable port pairs are an error here,
        not a surprise at steal time.
        """
        if not isinstance(topo, dict):
            raise ValueError(f"topology must be a dict, got {type(topo).__name__}")
        unknown = set(topo) - {"switches", "ports", "default_link", "links"}
        if unknown:
            raise ValueError(f"topology: unknown keys {sorted(unknown)}")
        switches = topo.get("switches")
        if not switches or not isinstance(switches, list) \
                or len(set(switches)) != len(switches) \
                or not all(isinstance(s, str) for s in switches):
            raise ValueError("topology: 'switches' must be a non-empty "
                             "list of unique strings")
        ports = topo.get("ports") or {}
        if not isinstance(ports, dict):
            raise ValueError("topology: 'ports' must be a dict "
                             "{port-name: switch}")
        swset = set(switches)
        for node, sw in sorted(ports.items()):
            if not isinstance(node, str) or node in swset:
                raise ValueError(f"topology: bad port name {node!r} "
                                 f"(must be a string, not a switch)")
            if sw not in swset:
                raise ValueError(f"topology: port {node!r} attaches to "
                                 f"unknown switch {sw!r} "
                                 f"(switches: {sorted(swset)})")
        missing = sorted(set(shells) - set(ports))
        if missing:
            raise ValueError(f"topology: shells {missing} have no port "
                             f"(every shell needs a 'ports' entry)")
        dflt = dict(topo.get("default_link")
                    or {"latency_ms": 0.1, "bw_ms": 0.0, "buffer": 4})
        dflt.setdefault("latency_ms", 0.1)
        dflt.setdefault("bw_ms", 0.0)
        dflt.setdefault("buffer", 4)
        _check_link_params("topology default_link", dflt["latency_ms"],
                           dflt["bw_ms"], dflt["buffer"])

        net = cls()
        net._mode = "links"
        net._ports = {str(k): str(v) for k, v in ports.items()}

        def add(src, dst, lat, bw, buf, where):
            if (src, dst) in net._links:
                raise ValueError(f"{where}: duplicate link "
                                 f"{src!r}->{dst!r}")
            net._links[(src, dst)] = Link(src, dst, float(lat),
                                          float(bw), buf)

        # port attachments: duplex links with default parameters
        for node, sw in sorted(net._ports.items()):
            add(node, sw, dflt["latency_ms"], dflt["bw_ms"],
                dflt["buffer"], "topology ports")
            add(sw, node, dflt["latency_ms"], dflt["bw_ms"],
                dflt["buffer"], "topology ports")

        nodes = swset | set(net._ports)
        for i, entry in enumerate(topo.get("links") or []):
            where = f"topology links[{i}]"
            if not isinstance(entry, dict):
                raise ValueError(f"{where}: must be a dict")
            src, dst = entry.get("src"), entry.get("dst")
            if src not in nodes or dst not in nodes or src == dst:
                raise ValueError(
                    f"{where}: pair {src!r}->{dst!r} must name two "
                    f"distinct declared nodes {sorted(nodes)}")
            lat = entry.get("latency_ms", dflt["latency_ms"])
            bw = entry.get("bw_ms", dflt["bw_ms"])
            buf = entry.get("buffer", dflt["buffer"])
            _check_link_params(where, lat, bw, buf)
            pairs = [(src, dst)]
            if entry.get("duplex", True):
                pairs.append((dst, src))
            for a, b in pairs:
                if (a, b) in net._links:
                    if a in swset and b in swset:
                        raise ValueError(f"{where}: duplicate link "
                                         f"{a!r}->{b!r}")
                    # port-attachment override
                    net._links[(a, b)] = Link(a, b, float(lat),
                                              float(bw), buf)
                else:
                    add(a, b, lat, bw, buf, where)

        # deterministic BFS over the switch graph, then precompute every
        # port-pair route; unreachable pairs fail here, at load time
        adj: dict[str, list[str]] = {s: [] for s in switches}
        for (a, b) in sorted(net._links):
            if a in swset and b in swset:
                adj[a].append(b)
        sw_path: dict[tuple[str, str], list[str]] = {}
        for start in switches:
            seen = {start: [start]}
            frontier = [start]
            while frontier:
                nxt = []
                for u in frontier:
                    for v in adj[u]:
                        if v not in seen:
                            seen[v] = seen[u] + [v]
                            nxt.append(v)
                frontier = nxt
            for end, path in seen.items():
                sw_path[(start, end)] = path
        port_names = sorted(net._ports)
        for a in port_names:
            for b in port_names:
                if a == b:
                    continue
                key = (net._ports[a], net._ports[b])
                if key not in sw_path:
                    raise ValueError(
                        f"topology: no switch path from {a!r} (on "
                        f"{key[0]!r}) to {b!r} (on {key[1]!r}) — add a "
                        f"'links' entry connecting the switches")
                path = sw_path[key]
                route = [net._links[(a, path[0])]]
                for u, v in zip(path, path[1:]):
                    route.append(net._links[(u, v)])
                route.append(net._links[(path[-1], b)])
                net._routes[(a, b)] = tuple(route)
        return net

    # -- introspection -------------------------------------------------------

    @property
    def active(self) -> bool:
        """True in links mode — False means the uniform scalar shim."""
        return self._mode == "links"

    @property
    def has_ingress(self) -> bool:
        """An explicit "ingress" port prices job *arrival* placement
        (ECT dispatch) in addition to cross-shell steals."""
        return "ingress" in self._ports

    @property
    def inflight(self) -> int:
        return len(self._active)

    def links(self):
        """Deterministically ordered link list (tests, stats)."""
        return [self._links[k] for k in sorted(self._links)]

    # -- cost model ----------------------------------------------------------

    def _walk(self, route, payload: float, now: float, loaded: bool,
              bounded: bool) -> float:
        """Store-and-forward end time of a `payload`-unit transfer
        entering `route` at `now`; `inf` if `bounded` and any buffer is
        full.  `loaded=False` ignores occupancy (the zero-load figure —
        exactly what the scalar model believed)."""
        t = now
        for link in route:
            if loaded:
                if bounded and link.inflight >= link.buffer:
                    return float("inf")
                start = link.busy_until if link.busy_until > t else t
            else:
                start = t
            t = start + link.latency_ms + payload * link.bw_ms
        return t

    def est_transfer_ms(self, src: str, dst: str, payload: float = 1.0,
                        now: float = 0.0, loaded: bool = True,
                        bounded: bool = True) -> float:
        """Estimated cost of moving `payload` units `src`->`dst` at `now`.

        Uniform mode: the scalar per-pair lookup, ignoring load and
        payload — byte-identical to the old `Fabric._transfer_ms`.
        Links mode: queue-aware store-and-forward walk; `inf` while a
        bounded buffer on the route is full (back off, thief).
        """
        if self._mode == "uniform":
            return self._pairs.get((src, dst), self._default)
        if src == dst:
            return 0.0
        end = self._walk(self._routes[(src, dst)], payload, now,
                         loaded, bounded)
        return end - now if end != float("inf") else end

    def reserve(self, src: str, dst: str, payload: float,
                now: float) -> Transfer:
        """Realize a transfer: occupy every link on the route and return
        the receipt.  The caller gates *before* reserving (a full buffer
        estimates `inf`), so reservation itself never refuses — an
        overcommitted link simply serializes, which is the cost the
        over-eager scalar model pays in `benchmarks/network_contention`.
        """
        if self._mode == "uniform":
            cost = self._pairs.get((src, dst), self._default)
            return Transfer(src, dst, payload, now, 0.0, cost, ())
        route = self._routes[(src, dst)]
        first = route[0]
        wait = first.busy_until - now if first.busy_until > now else 0.0
        t = now
        for link in route:
            start = link.busy_until if link.busy_until > t else t
            t = start + link.latency_ms + payload * link.bw_ms
            link.busy_until = t
            link.inflight += 1
            link.transfers += 1
            link.busy_ms += t - start
            if link.inflight > link.max_queue:
                link.max_queue = link.inflight
        tr = Transfer(src, dst, payload, now, wait, t - now, route)
        self._active.append(tr)
        self._pending.append(tr)
        self.version += 1
        return tr

    def advance(self, now: float) -> list[Transfer]:
        """Release every reserved transfer whose `t_done` has passed,
        freeing link buffer slots, and return them (oldest first).  The
        simulator calls this from "net" heap events; the daemon calls it
        each loop on wall clock."""
        if not self._active:
            return []
        done = [t for t in self._active if t.t_done <= now]
        if not done:
            return []
        self._active = [t for t in self._active if t.t_done > now]
        for tr in done:
            for link in tr.route:
                link.inflight -= 1
        self.version += 1
        done.sort(key=lambda t: (t.t_done, t.src, t.dst))
        return done

    def drain_releases(self) -> list[Transfer]:
        """Transfers reserved since the last drain — the simulator turns
        each into a timed "net" release event on its heap."""
        out, self._pending = self._pending, []
        return out

    # -- observability -------------------------------------------------------

    def gauges(self) -> dict:
        """Count-based link gauges (no clock needed): sampled by the
        flight recorder alongside occupancy/pending."""
        return {"links_busy": sum(1 for l in self._links.values()
                                  if l.inflight > 0),
                "transfers_inflight": len(self._active)}

    def stats(self) -> dict:
        """Per-link lifetime stats for `FlightRecorder.snapshot()`."""
        return {self._links[k].name: {
                    "transfers": self._links[k].transfers,
                    "busy_ms": self._links[k].busy_ms,
                    "max_queue": self._links[k].max_queue}
                for k in sorted(self._links)}
