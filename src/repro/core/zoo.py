"""Module builders for the FOS runtime: the accelerator zoo.

These are the FOS-JAX analogues of the paper's case-study accelerators:
  - mandelbrot : compute-bound fractal iteration (paper section 5.5)
  - sobel      : memory-bound 3x3 stencil (paper section 5.5)
  - matmul     : generic dense kernel (spector-style)
  - lm_forward : a reduced-config LM forward step from the model zoo

Each builder(mesh, footprint) -> ModuleProgram.  Bigger footprints map to
wider data-parallel slots; implementation alternatives additionally scale
internal work (e.g. mandelbrot unroll) the way the paper's DCT used bigger
module variants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.module import ModuleProgram


def _data_axis(mesh) -> str:
    return mesh.axis_names[0]


def build_mandelbrot(mesh, footprint: int, *, size: int = 256,
                     iters: int = 256) -> ModuleProgram:
    """Compute-bound: escape-time iteration over an image tile."""
    n_dev = int(np.prod(mesh.devices.shape))
    axis = _data_axis(mesh)

    def fn(_, grid_re, grid_im):
        zr = jnp.zeros_like(grid_re)
        zi = jnp.zeros_like(grid_im)
        count = jnp.zeros(grid_re.shape, jnp.int32)

        def body(i, carry):
            zr, zi, count = carry
            zr2, zi2 = zr * zr - zi * zi + grid_re, 2 * zr * zi + grid_im
            inside = zr2 * zr2 + zi2 * zi2 < 4.0
            return (jnp.where(inside, zr2, zr), jnp.where(inside, zi2, zi),
                    count + inside.astype(jnp.int32))

        zr, zi, count = jax.lax.fori_loop(0, iters, body, (zr, zi, count))
        return count

    shape = (size, size)
    spec = P(axis, None)
    return ModuleProgram(
        fn=fn,
        abstract_weights=None,
        abstract_inputs=(jax.ShapeDtypeStruct(shape, jnp.float32),
                         jax.ShapeDtypeStruct(shape, jnp.float32)),
        weight_pspecs=None,
        input_pspecs=(spec, spec),
        init_weights=None,
    )


def build_sobel(mesh, footprint: int, *, size: int = 1024) -> ModuleProgram:
    """Memory-bound 3x3 stencil over an image tile."""
    axis = _data_axis(mesh)
    kx = jnp.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], jnp.float32)
    ky = kx.T

    def fn(_, img):
        img4 = img[None, :, :, None]
        conv = functools.partial(
            jax.lax.conv_general_dilated, window_strides=(1, 1),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        gx = conv(img4, kx[:, :, None, None])
        gy = conv(img4, ky[:, :, None, None])
        return jnp.sqrt(gx * gx + gy * gy)[0, :, :, 0]

    spec = P(axis, None)
    return ModuleProgram(
        fn=fn, abstract_weights=None,
        abstract_inputs=(jax.ShapeDtypeStruct((size, size), jnp.float32),),
        weight_pspecs=None, input_pspecs=(spec,), init_weights=None)


def build_matmul(mesh, footprint: int, *, m: int = 512, k: int = 512,
                 n: int = 512) -> ModuleProgram:
    """Dense kernel with weights (vadd/spector stand-in)."""
    axis = _data_axis(mesh)

    def fn(w, x):
        return jnp.maximum(x @ w["a"] + w["b"], 0.0)

    def init(key):
        ka, kb = jax.random.split(key)
        return {"a": jax.random.normal(ka, (k, n), jnp.float32) * 0.02,
                "b": jnp.zeros((n,), jnp.float32)}

    return ModuleProgram(
        fn=fn,
        abstract_weights={"a": jax.ShapeDtypeStruct((k, n), jnp.float32),
                          "b": jax.ShapeDtypeStruct((n,), jnp.float32)},
        abstract_inputs=(jax.ShapeDtypeStruct((m, k), jnp.float32),),
        weight_pspecs={"a": P(None, None), "b": P(None)},
        input_pspecs=(P(axis, None),),
        init_weights=init)


def build_lm_forward(mesh, footprint: int, *, arch: str = "llama3.2-3b",
                     batch: int = 8, seq: int = 64) -> ModuleProgram:
    """Reduced-config LM teacher-forced forward (module-zoo integration)."""
    from repro import configs
    from repro.models import api, stack

    cfg = configs.get(arch, reduced=True)
    axis = _data_axis(mesh)

    def fn(params, tokens):
        h, _ = stack.forward(params, cfg, {"tokens": tokens})
        return stack.unembed(params, cfg, h[:, -1:])[:, 0]

    specs = api.param_specs(cfg)
    pspecs = jax.tree.map(lambda _: P(), specs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return ModuleProgram(
        fn=fn,
        abstract_weights=api.abstract_params(cfg),
        abstract_inputs=(jax.ShapeDtypeStruct((batch, seq), jnp.int32),),
        weight_pspecs=pspecs,
        input_pspecs=(P(axis, None),),
        init_weights=lambda key: api.init_params(cfg, key))
