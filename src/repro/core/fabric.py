"""Fabric: one scheduling contract over many shells.

A `Fabric` is a named collection of shells, each backed by its own
`SchedulerState`, behind a single submit/schedule/complete contract that
both executors (the discrete-event simulator and the live daemon) drive.
It is the scale-out layer FOS motivates with its standardised abstraction
argument: the space-time policy stays a pure per-shell core, and the
fabric adds the cross-shell arbitration —

  - a **global admission queue**: `submit` records a `FabricJob`;
    dispatch to a concrete shell is deferred to the next `schedule`
    call so placement sees current residency and load;
  - **locality-aware dispatch** (`PolicyConfig.locality`): a job goes to
    the shell already hosting its module resident (dodging the modeled
    reconfiguration penalty), falling back to least-loaded, with an
    optional hard `affinity=` override per job;
  - **cross-shell work stealing** (`PolicyConfig.steal`): a shell with
    free slots and no local backlog pulls unissued chunks queued behind
    the most-backlogged shell; the thief pays the reconfiguration
    penalty through the ordinary cost model, chunks are taken from the
    tail (preemption victims requeued at the front go last), and every
    chunk still runs exactly once;
  - a shared `CostModel` so online `est_chunk_ms` refinement on any
    shell improves placement everywhere;
  - a shared `ArrivalEstimator` (`PolicyConfig.reserve_mode ==
    "adaptive"`, core/arrivals.py): every admitted job is observed once
    at `submit`, and each shell sizes its effective interactive
    reservation from the predicted demand every scheduling pass —
    dispatch ECT and steal sizing treat reserved slots as capacity the
    batch class cannot use;
  - a shared `CheckpointManager` (`PolicyConfig.ckpt`,
    core/checkpoint.py): evicted chunks keep their progress, and
    **checkpointed migration** lets stealing move a checkpointed chunk
    to another shell when restore + transfer + its remaining fraction
    beats the victim draining its own backlog (the record is re-keyed
    to the thief's sub-request; shells with `ShellSpec.ckpt = False`
    neither save nor accept checkpoints);
  - **heterogeneity awareness**: each shell carries a relative `speed`
    (a chunk takes `est_chunk_ms / speed` there) and each (victim,
    thief) pair a cross-shell `transfer_ms` per stolen chunk
    (`PolicyConfig.transfer_ms` default, per-pair overrides from the
    `FabricDescriptor`); no-affinity dispatch ranks shells by estimated
    completion time instead of raw backlog, and a *priced* steal
    (nonzero transfer, or unequal speeds) is skipped when the transfer
    + the thief's (speed-scaled) service time would finish *later* than
    the victim clearing its own backlog.  At all speeds 1.0 + transfer
    0.0 the gate is inert and per-shell scheduling, chunk times and
    stealing are unchanged; the one deliberate homogeneous-path change
    is dispatch ranking, which weighs queues in estimated milliseconds
    (ECT) rather than raw chunk counts.

Identity model: all shells share one rid counter and one aid counter, so
request/assignment ids are unique fabric-wide, and a job's global id
(`FabricJob.gid`) equals the rid of its *primary* sub-request.  The
degenerate one-shell fabric therefore reproduces `SchedulerState`
behavior exactly — same rids, same event order, same floats — which is
how `Daemon(shell, ...)` and `simulate(registry, n_slots, ...)` keep
their seed semantics unchanged.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Iterable, Mapping

from repro.analysis import sanitizer
from repro.core.arrivals import ArrivalEstimator
from repro.core.checkpoint import CheckpointManager
from repro.core.network import FabricNetwork
from repro.core.registry import parse_transfer_pair
from repro.core.scheduler import Assignment, CostModel, PolicyConfig, \
    SchedulerState
from repro.core.slo import AdmissionController, AdmissionVerdict, \
    DEGRADE, QoSContract, REJECT


@dataclasses.dataclass(eq=False)
class FabricJob:
    """One submitted job, tracked fabric-wide across its sub-requests.

    `eq=False`: jobs compare (and hash) by identity.  Membership tests
    against the admission queue must mean "this very job object" — a
    value-based eq would deep-compare payload lists against every queued
    job on each `finished()` poll (O(queue x payload), and wrong in
    principle for two jobs with equal fields).
    """
    gid: int
    tenant: str
    module: str
    n_chunks: int
    payloads: list | None = None
    priority: int = 0
    deadline_ms: float | None = None
    affinity: str | None = None          # pin dispatch to this shell
    t_submit: float = 0.0
    t_finish: float | None = None
    done: int = 0
    failed: bool = False
    # (shell_name, rid) of every sub-request carrying this job's chunks
    subs: list = dataclasses.field(default_factory=list)
    # -- SLO admission (core/slo.py); all None/False without contracts --
    verdict: AdmissionVerdict | None = None
    degraded_from: str | None = None     # offered module a DEGRADE swapped
    rejected: bool = False               # shed at admission: never runs

    @property
    def complete(self) -> bool:
        return self.done >= self.n_chunks

    @property
    def deadline_at(self) -> float:
        if self.deadline_ms is None:
            return float("inf")
        return self.t_submit + self.deadline_ms


# -- schedlint memo contracts (checked by `python -m repro.analysis`) --------
#
# Every memo cache the incremental fabric leans on, with the version
# tokens its key covers.  The memo checker (analysis/memo.py) walks the
# cached computation and flags any read of versioned state the key
# misses; `folded` declares tokens covered indirectly, each with the
# argument for why.  Plain literals on purpose: the checker extracts
# them from the AST without importing this module.
MEMO_CONTRACTS = (
    {"name": "backlog_ms",
     "func": "Fabric._backlog_ms",
     "cache": "_backlog_cache",
     "key": ("state", "cost"),
     "folded": {}},
    {"name": "steal_fingerprint",
     "func": "Fabric._steal_from",
     "cache": "_steal_fail",
     "key": ("state", "cost", "reserve"),
     "folded": {
         "arrivals": "Fabric.schedule resamples every shell's "
                     "reservation from the estimator on every event "
                     "(sample_reserve), so an arrival-model change is "
                     "folded into the thief's _reserve_last — which "
                     "the fingerprint covers directly — before any "
                     "steal gate runs",
         "now": "the clock enters the gate only through the per-event "
                "reservation sample (_reserve_last, covered) and the "
                "demand memo, which keys on `now` itself; the drain/"
                "price comparison reads no absolute time (the "
                "load-aware transfer estimate does read `now`, but "
                "only on an active link network, where the cache is "
                "bypassed — see the `net` entry)",
         "net": "link-state reads (est_transfer_ms: busy_until, "
                "inflight) resolve to construction-time constants on "
                "the degenerate uniform topology, and the fingerprint "
                "cache is consulted only there — _steal bypasses it "
                "entirely whenever `network.active` (link occupancy "
                "moves without any shell/cost version bump, so no "
                "4-tuple fingerprint could stay sound)"}},
)


class Fabric:
    """Named shells behind a single scheduling contract.

    `shells` maps shell name -> slot count (speed 1.0), an
    `(n_slots, speed)` tuple, or anything with an `n_slots` attribute
    and optional `speed`, e.g. a ShellSpec.  All shells share one
    `PolicyConfig` and one `CostModel`.  `transfer` optionally maps
    `(victim, thief)` pairs (or `"victim->thief"` strings) to the
    modeled cross-shell payload-movement cost per stolen chunk,
    overriding `PolicyConfig.transfer_ms` for that direction.

    `network` optionally supplies a link-level `FabricNetwork`
    (core/network.py): transfer costs then come from queue-aware
    store-and-forward estimates over the topology instead of the
    scalar/per-pair model, and realized steals occupy links.  Omitted,
    the scalar knobs become a degenerate uniform topology — the
    byte-identical compatibility shim.  A link topology and per-pair
    `transfer` overrides are mutually exclusive (the topology already
    prices every pair).
    """

    def __init__(self, shells: Mapping[str, Any], registry,
                 policy: PolicyConfig | None = None,
                 cost: CostModel | None = None,
                 transfer: Mapping[Any, float] | None = None,
                 network: FabricNetwork | None = None):
        if not shells:
            raise ValueError("a fabric needs at least one shell")
        self.registry = registry
        self.policy = policy or PolicyConfig()
        self.cost = cost or CostModel(registry, self.policy.refine_alpha)
        self._rid = itertools.count()        # fabric-wide id spaces
        self._aid = itertools.count()
        # one checkpoint manager shared by every shell (like the cost
        # model): records follow chunks across shells when stealing
        # migrates them, and accounting is fabric-wide
        self.ckpt = CheckpointManager(registry, self.policy) \
            if self.policy.ckpt else None
        # predictive reservation: one arrival estimator shared by every
        # shell (like the cost model), fed once per job at admission —
        # a stolen sub-request's re-submit is a placement move, not an
        # arrival, so per-shell submits never observe
        self.arrivals = ArrivalEstimator(self.policy.arrival_alpha) \
            if self.policy.reserve_mode == "adaptive" else None
        # tenant -> last service time, shared by every shell: the
        # reservation's starvation waiver must see fabric-wide service
        # (a stolen sub-request of a tenant served elsewhere is
        # backlogged, not starved)
        self.tenant_service: dict[str, float] = {}
        self.states: dict[str, SchedulerState] = {}
        self.speeds: dict[str, float] = {}   # true relative clocks
        self.ckpt_capable: dict[str, bool] = {}
        for name, n in shells.items():
            if isinstance(n, int):
                n_slots, speed, capable = n, 1.0, True
            elif isinstance(n, tuple):
                (n_slots, speed), capable = n, True
            else:
                n_slots = n.n_slots
                speed = getattr(n, "speed", 1.0)
                # ShellSpec.ckpt = False models a shell without context
                # readback: it evicts lossily, and checkpoints never
                # migrate onto it
                capable = getattr(n, "ckpt", True)
            if speed <= 0:
                raise ValueError(f"shell {name!r} speed must be "
                                 f"positive, got {speed}")
            self.speeds[name] = speed
            self.ckpt_capable[name] = capable
            # a speed-blind policy plans as if every shell ran at the
            # reference clock (true times still apply in the executor)
            st = SchedulerState(
                n_slots, registry, self.policy, cost=self.cost,
                speed=speed if self.policy.speed_aware else 1.0,
                ckpt=self.ckpt, ckpt_capable=capable, name=name,
                arrivals=self.arrivals,
                tenant_last_ms=self.tenant_service)
            st._rid = self._rid
            st._aid = self._aid
            # progress estimation must know a stolen chunk's transfer
            # cost is overhead, not compute (mirrors the simulator's
            # reclaim accounting)
            st.transfer_of = (
                lambda nm: lambda rid: self._sub_transfer.get(
                    (nm, rid), 0.0))(name)
            self.states[name] = st
        self._transfer: dict[tuple[str, str], float] = {}
        for key, ms in (transfer or {}).items():
            pair = parse_transfer_pair(key, self.states)
            self._transfer[pair] = float(ms)
        if network is not None and network.active and self._transfer:
            raise ValueError(
                "per-pair transfer overrides and a link topology are "
                "mutually exclusive: the topology already prices every "
                "shell pair")
        # the interconnect model every transfer estimate reads; absent a
        # topology, the scalar knobs *are* the (uniform) network
        self.network = network if network is not None else \
            FabricNetwork.uniform(self.states, self.policy.transfer_ms,
                                  self._transfer)
        # SLO admission control: constructed lazily by the first
        # register_contract — a fabric with no contract never screens,
        # so the no-contract path stays byte-identical (core/slo.py)
        self.slo: AdmissionController | None = None
        self.jobs: dict[int, FabricJob] = {}
        # (shell_name, rid) -> (job, {local chunk id -> global chunk id})
        self._subs: dict[tuple[str, int], tuple[FabricJob, dict]] = {}
        # (shell_name, rid) -> transfer cost per chunk of a stolen
        # sub-request; the simulator realizes it in the chunk's time
        self._sub_transfer: dict[tuple[str, int], float] = {}
        # (shell, rid, chunk) identities retired by steals since the
        # last drain_moved(): the chunk now lives under a thief
        # sub-request, so executor bookkeeping keyed to the old identity
        # (the simulator's per-chunk transfer charges) must be released
        self._moved: list[tuple[str, int, int]] = []
        self._admission: deque[FabricJob] = deque()
        self._now = 0.0
        self.stats = {"dispatched": 0, "local_dispatch": 0,
                      "steals": 0, "stolen_chunks": 0}
        # -- incremental event-heap core (docs/simulator.md) -------------
        # shells whose scheduling state mutated since their last pass;
        # schedule() reschedules only these (plus time-triggered wakes).
        # External mutations reach it through SchedulerState.on_change,
        # so the daemon's direct-state path invalidates too.
        self._dirty: set[str] = set(self.states)
        for name, st in self.states.items():
            st.on_change = (lambda nm: lambda: self._dirty.add(nm))(name)
        # per-shell earliest instant a time trigger (starvation aging /
        # tenant-starvation waiver) can change a clean shell's outcome
        self._wake: dict[str, float] = {}
        # memoized exact _backlog_ms per shell, keyed by the shell's
        # mutation version and the shared cost model's version — the
        # cached value is the recomputation's own floats, so admission
        # ECT and steal pricing stay bit-identical to a fresh walk
        self._backlog_cache: dict[str, tuple[tuple[int, int], float]] = {}
        # failed steal attempts, keyed (victim, thief) -> the state
        # fingerprint they failed under: a fruitless _steal_from is a
        # pure function of (victim version, thief version, cost version,
        # thief reservation), so until one of those moves the same scan
        # would fail again and is skipped outright
        self._steal_fail: dict[tuple[str, str],
                               tuple[int, int, int, int]] = {}
        self._cost_seen = self.cost.version
        # network occupancy version last folded into the dirty set; on
        # the uniform shim the version never moves and the check below
        # is a single always-equal compare
        self._net_seen = self.network.version
        # reference switch: treat every shell as dirty on every pass
        # (the pre-refactor reschedule-everything core; equivalence
        # property tests and the throughput bench baseline drive it)
        self.full_reschedule = False
        # observability head (repro.obs.FlightRecorder.attach sets it).
        # None means detached: every hook below is a single attribute
        # test, so the default path allocates nothing and stays
        # byte-identical to the pre-recorder fabric
        self.obs = None

    @classmethod
    def from_registry(cls, registry, name: str,
                      policy: PolicyConfig | None = None) -> "Fabric":
        """Build from a registered `FabricDescriptor` (fabrics.json);
        shell speeds come from the ShellSpecs, per-pair transfer costs
        — or the link topology — from the descriptor."""
        desc = registry.fabric(name)
        net = FabricNetwork.from_topology(desc.network, desc.shells) \
            if desc.network else None
        return cls({s: registry.shell(s) for s in desc.shells},
                   registry, policy, transfer=desc.transfer_ms,
                   network=net)

    # -- queries --------------------------------------------------------------

    @property
    def names(self) -> list[str]:
        return list(self.states)

    @property
    def n_slots(self) -> int:
        return sum(st.alloc.n for st in self.states.values())

    def resolve(self, shell: str, a: Assignment) -> tuple[FabricJob, int]:
        """(job, global chunk id) for an assignment of a sub-request."""
        job, cmap = self._subs[(shell, a.rid)]
        return job, cmap[a.chunk]

    def sub(self, shell: str, rid: int):
        """(job, chunk map) for a sub-request, or None if the request was
        created directly on a shell state (legacy single-shell path)."""
        return self._subs.get((shell, rid))

    def transfer_cost(self, shell: str, rid: int) -> float:
        """Cross-shell transfer cost per chunk of a sub-request: the
        priced (victim, thief) cost if the sub-request was stolen onto
        this shell, else 0.0.  The simulator adds it to the stolen
        chunk's service time so the modeled payload movement is
        realized; the live daemon moves payloads in-process by
        reference, so there it remains a planning model."""
        return self._sub_transfer.get((shell, rid), 0.0)

    def finished(self, gid: int) -> bool:
        """Complete, rejected at admission, or failed with no chunk
        still in flight anywhere."""
        job = self.jobs[gid]
        if job.rejected or job.complete:
            return True
        if not job.failed:
            return False
        if job in self._admission:
            return False
        return all(self.states[s].requests[rid].finished
                   for s, rid in job.subs)

    def _pending(self, st: SchedulerState) -> int:
        return st.pending_chunks()

    @staticmethod
    def _hosts(st: SchedulerState, module: str) -> bool:
        """Does any of the shell's ranges host `module` resident?"""
        return any(m == module for m, _ in st.resident.values())

    def _load(self, name: str) -> float:
        """Backlog + occupancy, normalised by the shell's capacity in
        reference-speed slot equivalents (`n_slots * speed`)."""
        st = self.states[name]
        return (self._pending(st) + len(st.alloc.busy)) / (
            st.alloc.n * st.speed)

    def _min_fp(self, module: str) -> int:
        return min(self.registry.module(module).footprints)

    def est_transfer_ms(self, victim: str, thief: str,
                        payload: float = 1.0,
                        now: float | None = None,
                        bounded: bool = True) -> float:
        """Estimated cost of moving `payload` chunks victim->thief at
        `now` — what every steal / migration / dispatch gate consults.

        On the uniform shim this is the scalar per-pair lookup the old
        `_transfer_ms` did, byte-identical.  On a link topology it is
        the network's queue-aware store-and-forward walk — `inf` while
        a bounded buffer on the route is full — unless
        `PolicyConfig.congestion_aware` is off, which degrades to the
        zero-load figure (the scalar model's belief on real links: the
        baseline `benchmarks/network_contention.py` measures against).
        """
        return self.network.est_transfer_ms(
            victim, thief, payload,
            now=self._now if now is None else now,
            loaded=self.policy.congestion_aware, bounded=bounded)

    def _backlog_ms(self, name: str) -> float:
        """Estimated milliseconds of work already committed to a shell:
        queued chunks at the module's smallest footprint plus one chunk
        estimate per in-flight assignment (including its reconfiguration
        penalty, which that chunk is actually paying), at the shell's
        (decision) speed.

        Memoized on (shell mutation version, cost-model version): the
        cache returns the exact floats of the last recomputation, never
        an incrementally folded sum — float addition is not associative,
        and this estimate feeds bit-pinned placement decisions."""
        st = self.states[name]
        key = (st._version, self.cost.version)
        hit = self._backlog_cache.get(name)
        if hit is not None and hit[0] == key:
            if self.obs is not None:
                self.obs.backlog_hits += 1
            return hit[1]
        if self.obs is not None:
            self.obs.backlog_misses += 1
        total = 0.0
        for q in st.queues.values():
            for r in q:
                if r.pending > 0:
                    pend = float(r.pending)
                    if self.ckpt is not None:
                        # checkpointed victims only need their remaining
                        # fraction — a shell full of mostly-done chunks
                        # is a shorter queue than it looks
                        pend = max(0.0, pend
                                   - self.ckpt.pending_progress(r.rid))
                    total += pend * self.cost.est_chunk_ms(
                        r.module, self._min_fp(r.module), st.speed)
        for a in st.active.values():
            t = self.cost.est_chunk_ms(a.module, a.footprint,
                                       st.speed) * a.frac \
                + a.restore_ms + a.save_ms
            if a.reconfigure:
                t += self.policy.reconfig_penalty_ms
            total += t
        self._backlog_cache[name] = (key, total)
        return total

    def _job_ms(self, job: FabricJob, shell: str) -> float:
        """The job's own estimated work on a shell (min footprint)."""
        return job.n_chunks * self.cost.est_chunk_ms(
            job.module, self._min_fp(job.module),
            self.states[shell].speed)

    def _ect(self, name: str, job: FabricJob,
             backlog: Mapping[str, float] | None = None) -> float:
        """Estimated completion time of `job` if dispatched to `name`:
        the shell's committed backlog plus the job's own chunks, spread
        over the shell's slots at its speed.  This is what makes a fast
        shell with a short queue beat an idle slow one.  `backlog` is an
        optional precomputed per-shell `_backlog_ms` cache (one
        admission drain walks every queue once, not once per job)."""
        b = self._backlog_ms(name) if backlog is None else backlog[name]
        st = self.states[name]
        # a reserved slot is not capacity for this job's class: spread
        # the work over the slots its placements may actually use, so
        # dispatch stays consistent with the admission reservation
        # (sized at the fabric's clock — the shell's own may lag)
        slots = max(1, st.alloc.n
                    - st.reserve_for_class(job.priority, job.module,
                                           now=self._now))
        ect = (b + self._job_ms(job, name)) / slots
        if self.network.has_ingress:
            # an explicit "ingress" port prices arrival payload
            # movement: a shell behind a congested link finishes later
            # than its queue alone suggests.  Unbounded walk — dispatch
            # must rank shells even when every buffer is full
            ect += self.network.est_transfer_ms(
                "ingress", name, float(job.n_chunks), now=self._now,
                loaded=self.policy.congestion_aware, bounded=False)
        return ect

    # -- submission -----------------------------------------------------------

    def register_contract(self, contract: QoSContract,
                          now: float | None = None) -> None:
        """Attach (or replace) a tenant's `QoSContract`.  The first
        registration constructs the `AdmissionController`; from then on
        every `submit` is screened against all registered contracts.
        The contract's degraded module name is validated against the
        registry (rich KeyError on unknown names)."""
        if self.slo is None:
            self.slo = AdmissionController(self)
        self.slo.register(contract,
                          now=self._now if now is None else now)

    def submit(self, tenant: str, module: str, chunks,
               now: float = 0.0, priority: int = 0,
               deadline_ms: float | None = None,
               affinity: str | None = None,
               contract: QoSContract | None = None) -> FabricJob:
        """Admit a job.  `chunks` is a payload list (live mode) or a bare
        chunk count (simulation).  Dispatch to a shell happens at the
        next `schedule` call.

        `contract` registers (or refreshes) the tenant's `QoSContract`
        before screening — sugar for `register_contract` at the front
        door.  With any contract registered on the fabric, the
        `AdmissionController` screens the offered job first: a
        ``DEGRADE`` verdict transparently swaps `module` to the
        contract's degraded implementation (the offered name survives in
        `FabricJob.degraded_from`), and a ``REJECT`` verdict returns a
        job with `rejected=True` that never enters the admission queue
        — the caller reads the predicted violation off `job.verdict`.
        """
        self.registry.module(module)         # validates, nice KeyError
        if contract is not None:
            self.register_contract(contract, now=max(self._now, now))
        verdict: AdmissionVerdict | None = None
        degraded_from: str | None = None
        if self.slo is not None:
            t_adm = max(self._now, now)
            n_offered = chunks if isinstance(chunks, int) else len(chunks)
            verdict = self.slo.decide(tenant, module, n_offered, t_adm)
            if verdict.action == DEGRADE:
                # (an unknown affinity falls through to the rich
                # KeyError of the placement validation below)
                fit = self.states[affinity].alloc.n \
                    if affinity in self.states else \
                    max(st.alloc.n for st in self.states.values())
                if self._min_fp(verdict.degraded_to) <= fit:
                    degraded_from, module = module, verdict.degraded_to
                else:
                    # the degraded form can't be placed where this job
                    # must run; the offered form was already infeasible
                    verdict = AdmissionVerdict(
                        REJECT, tenant, violated=verdict.violated,
                        predicted_ms=verdict.predicted_ms,
                        reason=verdict.reason + "; degraded form does "
                        "not fit the target shell — rejected")
            if verdict.action == REJECT:
                self.slo.note_rejected(tenant, t_adm)
                gid = next(self._rid)
                job = FabricJob(gid, tenant, module, n_offered,
                                priority=priority,
                                deadline_ms=deadline_ms,
                                affinity=affinity, t_submit=now,
                                verdict=verdict, rejected=True)
                self.jobs[gid] = job
                self._now = t_adm
                if self.obs is not None:
                    self.obs.on_submit(job, self._now)
                return job
        min_fp = self._min_fp(module)
        if affinity is not None:
            if affinity not in self.states:
                raise KeyError(f"unknown shell {affinity!r} for "
                               f"affinity; fabric shells: "
                               f"{sorted(self.states)}")
            if min_fp > self.states[affinity].alloc.n:
                raise ValueError(
                    f"module {module!r} needs at least {min_fp} slots "
                    f"but shell {affinity!r} has "
                    f"{self.states[affinity].alloc.n}; the job would "
                    f"be unplaceable forever")
        elif min_fp > max(st.alloc.n for st in self.states.values()):
            raise ValueError(
                f"module {module!r} needs at least {min_fp} slots but "
                f"no shell in the fabric has that many; the job would "
                f"be unplaceable forever")
        if isinstance(chunks, int):
            n_chunks, payloads = chunks, None
        else:
            payloads = list(chunks)
            n_chunks = len(payloads)
        if self.arrivals is not None:
            # one observation per admitted job, before dispatch: the
            # predictive reservation reacts to the *offered* arrival
            # stream, independent of where the job lands
            self.arrivals.observe(
                priority, max(self._now, now),
                service_ms=self.cost.est_chunk_ms(module, min_fp),
                footprint=min_fp)
        gid = next(self._rid)
        job = FabricJob(gid, tenant, module, n_chunks, payloads,
                        priority=priority, deadline_ms=deadline_ms,
                        affinity=affinity, t_submit=now)
        self.jobs[gid] = job
        self._now = max(self._now, now)
        self._admission.append(job)
        if verdict is not None:
            job.verdict = verdict
            job.degraded_from = degraded_from
            self.slo.note_admitted(tenant, module, n_chunks, priority,
                                   self._now,
                                   degraded=degraded_from is not None)
        if self.obs is not None:
            self.obs.on_submit(job, self._now)
        return job

    def abort(self, gid: int) -> None:
        """Drop a job's unissued chunks on every shell (chunk error)."""
        job = self.jobs.get(gid)
        if job is None or job.failed:
            return
        job.failed = True
        try:
            self._admission.remove(job)       # not yet dispatched
        except ValueError:
            pass
        for shell, rid in job.subs:
            self.states[shell].abort(rid)

    # -- dispatch -------------------------------------------------------------

    def _pick_shell(self, job: FabricJob,
                    backlog: Mapping[str, float] | None = None) -> str:
        if job.affinity is not None:
            return job.affinity          # feasibility checked at submit
        # never dispatch to a shell the module's smallest footprint can
        # not fit even when empty — the job would queue there forever
        min_fp = self._min_fp(job.module)
        names = [n for n in self.names
                 if min_fp <= self.states[n].alloc.n]
        if self.policy.locality:
            resident = [n for n in names
                        if self._hosts(self.states[n], job.module)]
            if resident:
                names = resident
        order = {n: i for i, n in enumerate(self.names)}
        # estimated completion time, not raw backlog: an idle slow
        # shell loses to a busy fast one when the fast one still
        # finishes the job sooner (ties: load, then declaration order)
        return min(names, key=lambda n: (self._ect(n, job, backlog),
                                         self._load(n), order[n]))

    def _dispatch(self, job: FabricJob,
                  backlog: Mapping[str, float] | None = None) -> str:
        shell = self._pick_shell(job, backlog)
        st = self.states[shell]
        if self.policy.locality and self._hosts(st, job.module):
            self.stats["local_dispatch"] += 1
        st.submit(job.tenant, job.module, job.n_chunks,
                  payloads=job.payloads, now=job.t_submit,
                  priority=job.priority, deadline_ms=job.deadline_ms,
                  rid=job.gid)
        job.subs.append((shell, job.gid))
        self._subs[(shell, job.gid)] = (
            job, {i: i for i in range(job.n_chunks)})
        self.stats["dispatched"] += 1
        if self.obs is not None:
            self.obs.on_dispatch(job, shell, self._now)
        return shell

    # -- work stealing --------------------------------------------------------

    def _steal_from(self, victim: str, thief: str, now: float) -> int:
        """Move tail chunks of the victim shell's most-backlogged request
        onto the thief.  Returns the number of chunks moved.

        When the move has a heterogeneous price — a nonzero transfer
        cost for this pair, or unequal shell speeds — a candidate is
        skipped unless it wins: the transfer cost plus the thief's
        (speed-scaled) service time, plus the reconfiguration penalty if
        it does not already host the module, must beat the victim
        clearing its backlog locally.  With transfer 0 and equal speeds
        there is nothing to price and the gate is inert, so the
        homogeneous stealing contract is exactly the PR 2 behavior.
        """
        vst, tst = self.states[victim], self.states[thief]
        transfer = self.est_transfer_ms(victim, thief, now=now)
        priced = transfer > 0.0 or tst.speed != vst.speed
        # time for the victim to drain what it already has, per slot
        drain_ms = self._backlog_ms(victim) / vst.alloc.n \
            if priced or self.ckpt is not None else 0.0
        best, best_key = None, None
        # the thief's reservation and free-window count depend only on
        # (interactive-or-not, min footprint); memoize per scan so a
        # deep victim backlog costs a handful of computations, not one
        # per queued request
        win_cache: dict[tuple[bool, int], tuple[int, int]] = {}
        for q in vst.queues.values():
            for r in q:
                if r.pending <= 0:
                    continue
                entry = self._subs.get((victim, r.rid))
                if entry is None:
                    continue              # not fabric-managed: leave it
                min_fp = self._min_fp(r.module)
                if min_fp > tst.alloc.largest_free():
                    continue              # thief can't host this module
                # reserved slots are not steal targets: size the steal
                # to the windows this request's class may actually use
                # on the thief, and skip the candidate outright when
                # only reserved capacity is left over there
                ck = (r.priority >= self.policy.reserve_priority,
                      min_fp)
                if ck in win_cache:
                    reserve, n_win = win_cache[ck]
                else:
                    reserve = tst.reserve_for_class(
                        r.priority, r.module, now=now)
                    n_win = tst._n_free_ranges(
                        min_fp, within=tst.alloc.n - reserve)
                    win_cache[ck] = (reserve, n_win)
                if reserve > 0 and n_win == 0:
                    continue
                reconf_ms = 0.0 if self._hosts(tst, r.module) \
                    else self.policy.reconfig_penalty_ms
                # tail steals take pristine chunks only — checkpointed
                # ones sit at the front and move via the gated resume
                # path below, never at an unpriced tail steal
                pristine = r.pending
                if self.ckpt is not None:
                    pristine = 0
                    for c in reversed(r._chunks):
                        if self.ckpt.peek(r.rid, c) is not None:
                            break
                        pristine += 1
                if priced:
                    thief_ms = transfer + reconf_ms + \
                        self.cost.est_chunk_ms(r.module, min_fp,
                                               tst.speed)
                    tail_ok = thief_ms < drain_ms
                else:
                    tail_ok = True        # unpriced: always-steal contract
                if tail_ok and pristine > 0:
                    key = (-r.pending, r.rid, 0)
                    if best_key is None or key < best_key:
                        best, best_key = \
                            (r, entry, min_fp, "tail", n_win), key
                # checkpointed migration: the request's *front* pending
                # chunk is a preemption victim carrying a checkpoint;
                # move it (always gated, even on a homogeneous pair)
                # when restore + transfer + its remaining fraction beats
                # the victim draining its own backlog
                if self.ckpt is not None and self.ckpt_capable[thief] \
                        and r._chunks:
                    rec = self.ckpt.peek(r.rid, r._chunks[0])
                    if rec is not None:
                        move_ms = transfer + reconf_ms + \
                            self.ckpt.restore_cost_ms(
                                r.module, min_fp, tst.speed) + \
                            rec.remaining * self.cost.est_chunk_ms(
                                r.module, min_fp, tst.speed)
                        if move_ms < drain_ms:
                            key = (-r.pending, r.rid, 1)
                            if best_key is None or key < best_key:
                                best, best_key = \
                                    (r, entry, min_fp, "resume",
                                     n_win), key
        if best is None:
            return 0
        req, (job, cmap), min_fp, mode, n_win = best
        # steal what the thief can place right now: the count of free
        # aligned windows (outside any reservation the stolen class may
        # not enter) at the module's smallest footprint — raw free
        # slots over-count under fragmentation; stealing re-evaluates
        # on every event, so a deep backlog drains incrementally.  A
        # resume-steal moves exactly the one checkpointed front chunk.
        k = 1 if mode == "resume" else min(req.pending, max(1, n_win))
        # the stolen sub-request inherits the victim's aging anchor
        # (time since submit or last service), so starvation-aging
        # credit earned queueing behind the busy shell survives the move
        anchor = req.t_submit if req.t_last_served is None else \
            max(req.t_submit, req.t_last_served)
        taken = vst.steal_front(req.rid, k) if mode == "resume" \
            else vst.steal_pending(req.rid, k)
        if not taken:
            return 0
        # the taken chunks' (shell, rid, chunk) identities are retired
        # on every steal path — tail and resume alike — so executor
        # state keyed to them (per-chunk transfer charges) releases
        # exactly, including a previously-stolen chunk stolen again
        self._moved.extend((victim, req.rid, c) for c in taken)
        global_ids = [cmap[c] for c in taken]
        payloads = None if job.payloads is None else \
            [job.payloads[g] for g in global_ids]
        deadline = None if job.deadline_ms is None else \
            job.deadline_at - anchor
        sub = tst.submit(job.tenant, job.module, len(taken),
                         payloads=payloads, now=anchor,
                         priority=job.priority, deadline_ms=deadline)
        job.subs.append((thief, sub.rid))
        self._subs[(thief, sub.rid)] = (
            job, {i: g for i, g in enumerate(global_ids)})
        if self.network.active:
            # realize the move as timed link occupancy: a k-chunk batch
            # serializes store-and-forward over the route, so the
            # per-chunk realized price is the batch total split evenly
            # — under contention it exceeds the estimate the gate saw,
            # which is exactly the penalty the naive scalar model pays
            xfer = self.network.reserve(victim, thief,
                                        float(len(taken)), now)
            if xfer.total_ms > 0.0:
                self._sub_transfer[(thief, sub.rid)] = \
                    xfer.total_ms / len(taken)
            if self.obs is not None:
                self.obs.on_transfer_start(victim, thief, len(taken),
                                           xfer, now)
        elif transfer > 0.0:
            self._sub_transfer[(thief, sub.rid)] = transfer
        if self.ckpt is not None:
            # a stolen chunk's checkpoint follows it to the thief (its
            # context is part of the priced payload movement); a thief
            # without restore support drops the record instead
            for i, c in enumerate(taken):
                self.ckpt.rekey((req.rid, c), (sub.rid, i), shell=thief,
                                capable=self.ckpt_capable[thief])
        if self.obs is not None and mode == "resume":
            self.obs.on_ckpt_migrate(victim, thief, sub.rid, now)
        self.stats["steals"] += 1
        self.stats["stolen_chunks"] += len(taken)
        return len(taken)

    def _steal(self, now: float,
               placed: dict[str, set]) -> list[tuple[str, Assignment]]:
        out = []
        # victim ranking hoisted out of the thief loop: pendings only
        # change when a steal actually lands (steal_pending + the
        # thief's re-submit + its schedule call), so the ranked list is
        # rebuilt exactly then and the steal order stays byte-identical
        # to ranking from scratch per thief
        ranked: list[str] | None = None
        while True:
            moved = False
            for thief, tst in self.states.items():
                if tst.alloc.largest_free() == 0 or self._pending(tst):
                    continue              # busy, or has its own backlog
                if ranked is None:
                    ranked = sorted(
                        (n for n in self.states
                         if self._pending(self.states[n]) > 0),
                        key=lambda n: (-self._pending(self.states[n]), n))
                for victim in ranked:
                    if victim == thief:
                        continue
                    # a failed scan is pure in this fingerprint: every
                    # input _steal_from reads (victim queues + their
                    # checkpoint records, thief residency/allocation/
                    # reservation, cost estimates; `now` only through
                    # the already-sampled reservation) is covered by it.
                    # On an active link network the transfer estimate
                    # also reads link occupancy and the clock — state no
                    # shell version covers — so the cache is bypassed
                    # there (MEMO_CONTRACTS "net")
                    fp = None
                    if not self.network.active:
                        fp = (self.states[victim]._version, tst._version,
                              self.cost.version, tst._reserve_last)
                        if self._steal_fail.get((victim, thief)) == fp:
                            if self.obs is not None:
                                # counted as a probe+miss at snapshot
                                # time, never traced (see FlightRecorder)
                                self.obs.steal_fp_skips += 1
                            continue
                    taken = self._steal_from(victim, thief, now)
                    if self.obs is not None:
                        self.obs.on_steal(victim, thief, now,
                                          hit=taken > 0, chunks=taken)
                    if taken:
                        out.extend((thief, a) for a in
                                   tst.schedule(now, placed=placed[thief]))
                        moved = True
                        ranked = None
                        break
                    if fp is not None:
                        self._steal_fail[(victim, thief)] = fp
            if not moved:
                return out

    # -- scheduling -----------------------------------------------------------

    def schedule(self, now: float | None = None) \
            -> list[tuple[str, Assignment]]:
        """Dispatch admitted jobs, fill the free slots of every *dirty*
        shell, then let idle shells steal.  Returns (shell_name,
        Assignment) pairs; preemption victims are reported through
        `drain_preempted()`.

        A shell not in the dirty set is at a scheduling fixpoint: its
        last pass ran to "nothing more placeable" and nothing since has
        changed what _pick/_choose/_preempt_for would see.  Skipping it
        is therefore a byte-identical no-op elision, provided every way
        the fixpoint can break re-dirties the shell first: external
        mutations (submit/complete/abort/steal — SchedulerState.on_change),
        admission dispatch, a cost-model estimate moving (version check
        below), the effective reservation changing (sampled here every
        event, which also keeps reserve_history exact), a starvation
        boundary crossing (the wake times), or the same-pass preemption
        guard expiring (placed assignments become evictable at the next
        event).  docs/simulator.md derives the invariant."""
        now = self._now if now is None else max(self._now, now)
        self._now = now
        if sanitizer.SANITIZE:
            # every shell, every event — the *clean* shells are the ones
            # a touch-less mutation would silently corrupt (the elision
            # below would keep treating them as scheduling fixpoints)
            for st in self.states.values():
                sanitizer.check(st)
        run, self._dirty = self._dirty, set()
        if self.full_reschedule:
            run.update(self.states)
        if self.cost.version != self._cost_seen:
            # a refined estimate moves placement and steal economics on
            # every shell at once (the model is shared)
            self._cost_seen = self.cost.version
            run.update(self.states)
        if self.network.version != self._net_seen:
            # link occupancy moved (a reserve or a release): steal
            # economics and ingress-priced dispatch changed on every
            # shell at once, with no shell-local version bump to show
            # for it — the network is shared, like the cost model
            self._net_seen = self.network.version
            run.update(self.states)
        if self._admission:
            # one backlog walk for the whole drain; each dispatched
            # job's own work is folded in incrementally, which is
            # exactly what recomputing _backlog_ms would return
            backlog = {n: self._backlog_ms(n) for n in self.states}
            while self._admission:
                job = self._admission.popleft()
                if not job.failed:
                    shell = self._dispatch(job, backlog)
                    backlog[shell] += self._job_ms(job, shell)
                    run.add(shell)
        for name, st in self.states.items():
            # the reschedule-everything core advanced every shell's
            # clock and sampled its reservation on every pass; both are
            # per-event effects, not per-dirty-shell effects
            st._now = max(st._now, now)
            if name in run:
                continue
            prev = st._reserve_last
            if st.sample_reserve(now) != prev:
                run.add(name)             # reservation moved: re-place
            elif now >= self._wake.get(name, float("-inf")):
                run.add(name)             # aging/starvation boundary
        # one placed-set per shell for the whole pass: an assignment
        # issued here must not be preempted by a later steal-path
        # schedule call at the same instant (same-pass churn guard)
        placed: dict[str, set] = {name: set() for name in self.states}
        out = [(name, a) for name, st in self.states.items()
               if name in run
               for a in st.schedule(now, placed=placed[name])]
        if self.policy.steal and self.policy.elastic \
                and len(self.states) > 1 and run:
            # with no shell rescheduled, nothing a steal gate reads has
            # changed since the last pass ended with "no steal lands"
            out.extend(self._steal(now, placed))
        for name, st in self.states.items():
            if name in run:
                self._wake[name] = st.next_wake(now)
            if placed[name] and self.policy.preemptive \
                    and st.pending_chunks() > 0:
                # assignments issued this pass were preemption-exempt
                # (same-pass churn guard); at the next event they are
                # fair game, so the still-backlogged shell must re-run
                self._dirty.add(name)
        if self.obs is not None:
            self.obs.on_pass(now, run, len(self.states), out)
        return out

    def complete(self, shell: str, a: Assignment,
                 now: float = 0.0) -> bool:
        """Record a finished chunk.  False when the assignment was
        preempted first (stale — the executor discards the result)."""
        st = self.states[shell]
        if not st.complete(a, now=now):
            return False
        self._now = max(self._now, now)
        if self.obs is not None:
            self.obs.on_complete(shell, a, st.requests[a.rid].tenant, now)
        if st.requests[a.rid].finished:
            # a drained stolen sub-request schedules no more chunks;
            # release its transfer-price record (long-daemon hygiene)
            self._sub_transfer.pop((shell, a.rid), None)
        entry = self._subs.get((shell, a.rid))
        if entry is not None:
            job, _ = entry
            job.done += 1
            if job.complete and job.t_finish is None:
                job.t_finish = now
                if self.slo is not None:
                    # score the finished job against its contract's
                    # deadline (attainment accounting; no-op for
                    # non-contract tenants)
                    self.slo.record_completion(
                        job.tenant, now - job.t_submit,
                        job.deadline_ms, now)
        return True

    def drain_moved(self) -> list[tuple[str, int, int]]:
        """Chunk identities retired by steals since the last drain —
        the chunk now lives under a thief sub-request, so executor
        bookkeeping keyed to `(shell, rid, chunk)` must be released."""
        out, self._moved = self._moved, []
        return out

    def drain_preempted(self) -> list[tuple[str, Assignment]]:
        """Victim assignments since the last drain, tagged by shell; the
        executor must cancel them (chunks are already requeued)."""
        out = [(name, a) for name, st in self.states.items()
               for a in st.drain_preempted()]
        if self.obs is not None and out:
            self.obs.on_preempted(out, self._now)
        return out
