"""Fabric: one scheduling contract over many shells.

A `Fabric` is a named collection of shells, each backed by its own
`SchedulerState`, behind a single submit/schedule/complete contract that
both executors (the discrete-event simulator and the live daemon) drive.
It is the scale-out layer FOS motivates with its standardised abstraction
argument: the space-time policy stays a pure per-shell core, and the
fabric adds the cross-shell arbitration —

  - a **global admission queue**: `submit` records a `FabricJob`;
    dispatch to a concrete shell is deferred to the next `schedule`
    call so placement sees current residency and load;
  - **locality-aware dispatch** (`PolicyConfig.locality`): a job goes to
    the shell already hosting its module resident (dodging the modeled
    reconfiguration penalty), falling back to least-loaded, with an
    optional hard `affinity=` override per job;
  - **cross-shell work stealing** (`PolicyConfig.steal`): a shell with
    free slots and no local backlog pulls unissued chunks queued behind
    the most-backlogged shell; the thief pays the reconfiguration
    penalty through the ordinary cost model, chunks are taken from the
    tail (preemption victims requeued at the front go last), and every
    chunk still runs exactly once;
  - a shared `CostModel` so online `est_chunk_ms` refinement on any
    shell improves placement everywhere.

Identity model: all shells share one rid counter and one aid counter, so
request/assignment ids are unique fabric-wide, and a job's global id
(`FabricJob.gid`) equals the rid of its *primary* sub-request.  The
degenerate one-shell fabric therefore reproduces `SchedulerState`
behavior exactly — same rids, same event order, same floats — which is
how `Daemon(shell, ...)` and `simulate(registry, n_slots, ...)` keep
their seed semantics unchanged.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Iterable, Mapping

from repro.core.scheduler import Assignment, CostModel, PolicyConfig, \
    SchedulerState


@dataclasses.dataclass
class FabricJob:
    """One submitted job, tracked fabric-wide across its sub-requests."""
    gid: int
    tenant: str
    module: str
    n_chunks: int
    payloads: list | None = None
    priority: int = 0
    deadline_ms: float | None = None
    affinity: str | None = None          # pin dispatch to this shell
    t_submit: float = 0.0
    t_finish: float | None = None
    done: int = 0
    failed: bool = False
    # (shell_name, rid) of every sub-request carrying this job's chunks
    subs: list = dataclasses.field(default_factory=list)

    @property
    def complete(self) -> bool:
        return self.done >= self.n_chunks

    @property
    def deadline_at(self) -> float:
        if self.deadline_ms is None:
            return float("inf")
        return self.t_submit + self.deadline_ms


class Fabric:
    """Named shells behind a single scheduling contract.

    `shells` maps shell name -> slot count (or anything with an
    `n_slots` attribute, e.g. a ShellSpec).  All shells share one
    `PolicyConfig` and one `CostModel`.
    """

    def __init__(self, shells: Mapping[str, Any], registry,
                 policy: PolicyConfig | None = None,
                 cost: CostModel | None = None):
        if not shells:
            raise ValueError("a fabric needs at least one shell")
        self.registry = registry
        self.policy = policy or PolicyConfig()
        self.cost = cost or CostModel(registry, self.policy.refine_alpha)
        self._rid = itertools.count()        # fabric-wide id spaces
        self._aid = itertools.count()
        self.states: dict[str, SchedulerState] = {}
        for name, n in shells.items():
            n_slots = n if isinstance(n, int) else n.n_slots
            st = SchedulerState(n_slots, registry, self.policy,
                                cost=self.cost)
            st._rid = self._rid
            st._aid = self._aid
            self.states[name] = st
        self.jobs: dict[int, FabricJob] = {}
        # (shell_name, rid) -> (job, {local chunk id -> global chunk id})
        self._subs: dict[tuple[str, int], tuple[FabricJob, dict]] = {}
        self._admission: deque[FabricJob] = deque()
        self._now = 0.0
        self.stats = {"dispatched": 0, "local_dispatch": 0,
                      "steals": 0, "stolen_chunks": 0}

    @classmethod
    def from_registry(cls, registry, name: str,
                      policy: PolicyConfig | None = None) -> "Fabric":
        """Build from a registered `FabricDescriptor` (fabrics.json)."""
        desc = registry.fabric(name)
        return cls({s: registry.shell(s).n_slots for s in desc.shells},
                   registry, policy)

    # -- queries --------------------------------------------------------------

    @property
    def names(self) -> list[str]:
        return list(self.states)

    @property
    def n_slots(self) -> int:
        return sum(st.alloc.n for st in self.states.values())

    def resolve(self, shell: str, a: Assignment) -> tuple[FabricJob, int]:
        """(job, global chunk id) for an assignment of a sub-request."""
        job, cmap = self._subs[(shell, a.rid)]
        return job, cmap[a.chunk]

    def sub(self, shell: str, rid: int):
        """(job, chunk map) for a sub-request, or None if the request was
        created directly on a shell state (legacy single-shell path)."""
        return self._subs.get((shell, rid))

    def finished(self, gid: int) -> bool:
        """Complete, or failed with no chunk still in flight anywhere."""
        job = self.jobs[gid]
        if job.complete:
            return True
        if not job.failed:
            return False
        if job in self._admission:
            return False
        return all(self.states[s].requests[rid].finished
                   for s, rid in job.subs)

    def _pending(self, st: SchedulerState) -> int:
        return st.pending_chunks()

    @staticmethod
    def _hosts(st: SchedulerState, module: str) -> bool:
        """Does any of the shell's ranges host `module` resident?"""
        return any(m == module for m, _ in st.resident.values())

    def _load(self, st: SchedulerState) -> float:
        """Backlog + occupancy, normalised by shell size."""
        return (self._pending(st) + len(st.alloc.busy)) / st.alloc.n

    # -- submission -----------------------------------------------------------

    def submit(self, tenant: str, module: str, chunks,
               now: float = 0.0, priority: int = 0,
               deadline_ms: float | None = None,
               affinity: str | None = None) -> FabricJob:
        """Admit a job.  `chunks` is a payload list (live mode) or a bare
        chunk count (simulation).  Dispatch to a shell happens at the
        next `schedule` call."""
        self.registry.module(module)         # validates, nice KeyError
        if affinity is not None and affinity not in self.states:
            raise KeyError(f"unknown shell {affinity!r} for affinity; "
                           f"fabric shells: {sorted(self.states)}")
        if isinstance(chunks, int):
            n_chunks, payloads = chunks, None
        else:
            payloads = list(chunks)
            n_chunks = len(payloads)
        gid = next(self._rid)
        job = FabricJob(gid, tenant, module, n_chunks, payloads,
                        priority=priority, deadline_ms=deadline_ms,
                        affinity=affinity, t_submit=now)
        self.jobs[gid] = job
        self._now = max(self._now, now)
        self._admission.append(job)
        return job

    def abort(self, gid: int) -> None:
        """Drop a job's unissued chunks on every shell (chunk error)."""
        job = self.jobs.get(gid)
        if job is None or job.failed:
            return
        job.failed = True
        try:
            self._admission.remove(job)       # not yet dispatched
        except ValueError:
            pass
        for shell, rid in job.subs:
            self.states[shell].abort(rid)

    # -- dispatch -------------------------------------------------------------

    def _pick_shell(self, job: FabricJob) -> str:
        if job.affinity is not None:
            return job.affinity
        names = self.names
        if self.policy.locality:
            resident = [n for n in names
                        if self._hosts(self.states[n], job.module)]
            if resident:
                names = resident
        order = {n: i for i, n in enumerate(self.names)}
        return min(names, key=lambda n: (self._load(self.states[n]),
                                         order[n]))

    def _dispatch(self, job: FabricJob) -> str:
        shell = self._pick_shell(job)
        st = self.states[shell]
        if self.policy.locality and self._hosts(st, job.module):
            self.stats["local_dispatch"] += 1
        st.submit(job.tenant, job.module, job.n_chunks,
                  payloads=job.payloads, now=job.t_submit,
                  priority=job.priority, deadline_ms=job.deadline_ms,
                  rid=job.gid)
        job.subs.append((shell, job.gid))
        self._subs[(shell, job.gid)] = (
            job, {i: i for i in range(job.n_chunks)})
        self.stats["dispatched"] += 1
        return shell

    # -- work stealing --------------------------------------------------------

    def _steal_from(self, victim: str, thief: str, now: float) -> int:
        """Move tail chunks of the victim shell's most-backlogged request
        onto the thief.  Returns the number of chunks moved."""
        vst, tst = self.states[victim], self.states[thief]
        best, best_key = None, None
        for q in vst.queues.values():
            for r in q:
                if r.pending <= 0:
                    continue
                entry = self._subs.get((victim, r.rid))
                if entry is None:
                    continue              # not fabric-managed: leave it
                min_fp = min(self.registry.module(r.module).footprints)
                if min_fp > tst.alloc.largest_free():
                    continue              # thief can't host this module
                key = (-r.pending, r.rid)
                if best_key is None or key < best_key:
                    best, best_key = (r, entry, min_fp), key
        if best is None:
            return 0
        req, (job, cmap), min_fp = best
        # steal what the thief can place right now: the count of free
        # aligned windows at the module's smallest footprint (raw free
        # slots over-count under fragmentation); stealing re-evaluates
        # on every event, so a deep backlog drains incrementally
        k = min(req.pending, max(1, tst._n_free_ranges(min_fp)))
        # the stolen sub-request inherits the victim's aging anchor
        # (time since submit or last service), so starvation-aging
        # credit earned queueing behind the busy shell survives the move
        anchor = req.t_submit if req.t_last_served is None else \
            max(req.t_submit, req.t_last_served)
        taken = vst.steal_pending(req.rid, k)
        if not taken:
            return 0
        global_ids = [cmap[c] for c in taken]
        payloads = None if job.payloads is None else \
            [job.payloads[g] for g in global_ids]
        deadline = None if job.deadline_ms is None else \
            job.deadline_at - anchor
        sub = tst.submit(job.tenant, job.module, len(taken),
                         payloads=payloads, now=anchor,
                         priority=job.priority, deadline_ms=deadline)
        job.subs.append((thief, sub.rid))
        self._subs[(thief, sub.rid)] = (
            job, {i: g for i, g in enumerate(global_ids)})
        self.stats["steals"] += 1
        self.stats["stolen_chunks"] += len(taken)
        return len(taken)

    def _steal(self, now: float,
               placed: dict[str, set]) -> list[tuple[str, Assignment]]:
        out = []
        while True:
            moved = False
            for thief, tst in self.states.items():
                if tst.alloc.largest_free() == 0 or self._pending(tst):
                    continue              # busy, or has its own backlog
                victims = sorted(
                    (n for n in self.states
                     if n != thief and self._pending(self.states[n]) > 0),
                    key=lambda n: (-self._pending(self.states[n]), n))
                for victim in victims:
                    if self._steal_from(victim, thief, now):
                        out.extend((thief, a) for a in
                                   tst.schedule(now, placed=placed[thief]))
                        moved = True
                        break
            if not moved:
                return out

    # -- scheduling -----------------------------------------------------------

    def schedule(self, now: float | None = None) \
            -> list[tuple[str, Assignment]]:
        """Dispatch admitted jobs, fill every shell's free slots, then
        let idle shells steal.  Returns (shell_name, Assignment) pairs;
        preemption victims are reported through `drain_preempted()`."""
        now = self._now if now is None else max(self._now, now)
        self._now = now
        while self._admission:
            job = self._admission.popleft()
            if not job.failed:
                self._dispatch(job)
        # one placed-set per shell for the whole pass: an assignment
        # issued here must not be preempted by a later steal-path
        # schedule call at the same instant (same-pass churn guard)
        placed: dict[str, set] = {name: set() for name in self.states}
        out = [(name, a) for name, st in self.states.items()
               for a in st.schedule(now, placed=placed[name])]
        if self.policy.steal and self.policy.elastic \
                and len(self.states) > 1:
            out.extend(self._steal(now, placed))
        return out

    def complete(self, shell: str, a: Assignment,
                 now: float = 0.0) -> bool:
        """Record a finished chunk.  False when the assignment was
        preempted first (stale — the executor discards the result)."""
        st = self.states[shell]
        if not st.complete(a, now=now):
            return False
        self._now = max(self._now, now)
        entry = self._subs.get((shell, a.rid))
        if entry is not None:
            job, _ = entry
            job.done += 1
            if job.complete and job.t_finish is None:
                job.t_finish = now
        return True

    def drain_preempted(self) -> list[tuple[str, Assignment]]:
        """Victim assignments since the last drain, tagged by shell; the
        executor must cancel them (chunks are already requeued)."""
        return [(name, a) for name, st in self.states.items()
                for a in st.drain_preempted()]
