"""Logical hardware abstraction: JSON registry of shells and modules.

Mirrors the paper's section 4.2: shells and accelerators are described by
minimal JSON records; the runtime and 'generic drivers' (the daemon's invoke
path) work from these descriptors alone, so shells and modules can be
swapped without touching any other component.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
from pathlib import Path
from typing import Any

from repro.core.shell import ShellSpec


@dataclasses.dataclass(frozen=True)
class ImplAlt:
    """One implementation alternative (paper: bitstreams of varying size).

    Recognised `meta` keys: `true_chunk_ms` (simulator: actual service
    time when the estimate is deliberately wrong), `ckpt_save_ms` /
    `ckpt_restore_ms` (per-implementation context save/restore cost
    overriding `PolicyConfig.ckpt_save_ms`/`ckpt_restore_ms` — a
    state-heavy accelerator checkpoints slower than a stateless one).
    """
    name: str
    footprint: int                 # slots occupied (power of two)
    est_chunk_ms: float = 0.0      # scheduler cost model; refined online
    meta: dict = dataclasses.field(default_factory=dict)

    def to_json(self):
        return {"name": self.name, "footprint": self.footprint,
                "est_chunk_ms": self.est_chunk_ms, "meta": self.meta}

    @staticmethod
    def from_json(d):
        return ImplAlt(d["name"], d["footprint"],
                       d.get("est_chunk_ms", 0.0), d.get("meta", {}))


@dataclasses.dataclass(frozen=True)
class ModuleDescriptor:
    """Paper Listing 2: accelerator descriptor.

    `entrypoint` is an importable "pkg.mod:fn" returning a ModuleBuilder —
    the analogue of the bitstream file reference.  `registers` (the ADR-map
    analogue) is the module's abstract I/O signature, auto-filled at first
    compile, which the daemon's generic driver uses to invoke any module
    without module-specific host code.
    """
    name: str
    entrypoint: str
    impls: tuple[ImplAlt, ...]
    kind: str = "fn"               # fn | decode | prefill | train
    registers: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def to_json(self):
        return {"name": self.name, "entrypoint": self.entrypoint,
                "kind": self.kind,
                "impls": [i.to_json() for i in self.impls],
                "registers": self.registers, "meta": self.meta}

    @staticmethod
    def from_json(d):
        return ModuleDescriptor(
            d["name"], d["entrypoint"],
            tuple(ImplAlt.from_json(i) for i in d["impls"]),
            d.get("kind", "fn"), d.get("registers", {}), d.get("meta", {}))

    def impl_for(self, footprint: int) -> ImplAlt | None:
        for i in self.impls:
            if i.footprint == footprint:
                return i
        return None

    @property
    def footprints(self) -> list[int]:
        return sorted(i.footprint for i in self.impls)

    def load_builder(self):
        mod, _, fn = self.entrypoint.partition(":")
        return getattr(importlib.import_module(mod), fn)


def parse_transfer_pair(key, shells) -> tuple[str, str]:
    """Validate a cross-shell transfer key — a `"victim->thief"` string
    or a `(victim, thief)` tuple over `shells` — and return the pair.
    Shared by `Registry.register_fabric` and `Fabric.__init__` so both
    surfaces parse and reject identically."""
    pair = tuple(key.split("->")) if isinstance(key, str) else tuple(key)
    if len(pair) != 2 or any(s not in shells for s in pair):
        raise ValueError(
            f"transfer pair {key!r} must name two of the fabric's "
            f"shells {sorted(shells)} as '<victim>-><thief>'")
    return pair


@dataclasses.dataclass(frozen=True)
class FabricDescriptor:
    """A registered fabric: an ordered list of shell names scheduled as
    one unit (core/fabric.py).  Like shells and modules, a fabric is a
    serialisable descriptor (fabrics.json), so the scale-out topology is
    swappable without touching any other component.

    `transfer_ms` maps `"victim->thief"` shell pairs to the modeled
    cross-shell payload-movement cost per stolen chunk, overriding the
    fabric-wide `PolicyConfig.transfer_ms` default for that direction
    (e.g. boards on different hosts cost more than same-host shells).

    `network` optionally describes a link-level interconnect topology
    (core/network.py JSON schema: switches, ports, default_link,
    links) replacing the scalar model wholesale; it is mutually
    exclusive with `transfer_ms`.  Both are validated *here*, at
    construction/`from_json` time, with an error naming the offending
    pair or topology entry — a malformed descriptor must fail at load,
    not later at steal time.
    """
    name: str
    shells: tuple[str, ...]
    transfer_ms: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)
    network: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        for pair in self.transfer_ms:
            # descriptors must stay JSON-serialisable: tuple keys would
            # register fine but crash every later save()
            if not isinstance(pair, str):
                raise ValueError(
                    f"fabric {self.name!r}: descriptor transfer_ms "
                    f"keys must be '<victim>-><thief>' strings, got "
                    f"{pair!r}")
            parse_transfer_pair(pair, self.shells)
        if self.network:
            if self.transfer_ms:
                raise ValueError(
                    f"fabric {self.name!r}: 'network' topology and "
                    f"per-pair 'transfer_ms' are mutually exclusive — "
                    f"the topology already prices every shell pair")
            from repro.core.network import validate_topology
            try:
                validate_topology(self.network, self.shells)
            except ValueError as e:
                raise ValueError(
                    f"fabric {self.name!r}: invalid network "
                    f"topology: {e}") from e

    def to_json(self):
        d = {"name": self.name, "shells": list(self.shells),
             "transfer_ms": self.transfer_ms, "meta": self.meta}
        if self.network:
            d["network"] = self.network
        return d

    @staticmethod
    def from_json(d):
        return FabricDescriptor(d["name"], tuple(d["shells"]),
                                d.get("transfer_ms", {}),
                                d.get("meta", {}),
                                d.get("network", {}))


class Registry:
    """Central JSON-backed registry (paper: 'JSON based registry')."""

    def __init__(self):
        self.shells: dict[str, ShellSpec] = {}
        self.modules: dict[str, ModuleDescriptor] = {}
        self.fabrics: dict[str, FabricDescriptor] = {}

    # -- registration --------------------------------------------------------

    def register_shell(self, spec: ShellSpec) -> None:
        self.shells[spec.name] = spec

    def register_module(self, desc: ModuleDescriptor) -> None:
        self.modules[desc.name] = desc

    def register_fabric(self, desc: FabricDescriptor) -> None:
        # transfer pairs and the network topology were already validated
        # at descriptor construction (FabricDescriptor.__post_init__);
        # the registry only adds the shell-existence check
        for s in desc.shells:
            self.shell(s)              # fail fast on unknown shell names
        self.fabrics[desc.name] = desc

    def module(self, name: str) -> ModuleDescriptor:
        if name not in self.modules:
            raise KeyError(f"unknown module {name!r}; "
                           f"registered: {sorted(self.modules)}")
        return self.modules[name]

    def shell(self, name: str) -> ShellSpec:
        if name not in self.shells:
            raise KeyError(f"unknown shell {name!r}; "
                           f"registered: {sorted(self.shells)}")
        return self.shells[name]

    def fabric(self, name: str) -> FabricDescriptor:
        if name not in self.fabrics:
            raise KeyError(f"unknown fabric {name!r}; "
                           f"registered: {sorted(self.fabrics)}")
        return self.fabrics[name]

    # -- persistence ----------------------------------------------------------

    def save(self, path: str | Path) -> None:
        path = Path(path)
        path.mkdir(parents=True, exist_ok=True)
        (path / "shells.json").write_text(json.dumps(
            {k: v.to_json() for k, v in self.shells.items()}, indent=2))
        (path / "modules.json").write_text(json.dumps(
            {k: v.to_json() for k, v in self.modules.items()}, indent=2))
        (path / "fabrics.json").write_text(json.dumps(
            {k: v.to_json() for k, v in self.fabrics.items()}, indent=2))

    @staticmethod
    def load(path: str | Path) -> "Registry":
        path = Path(path)
        reg = Registry()
        shells = json.loads((path / "shells.json").read_text())
        modules = json.loads((path / "modules.json").read_text())
        for v in shells.values():
            reg.register_shell(ShellSpec.from_json(v))
        for v in modules.values():
            reg.register_module(ModuleDescriptor.from_json(v))
        fabrics_path = path / "fabrics.json"   # absent in pre-fabric saves
        if fabrics_path.exists():
            for v in json.loads(fabrics_path.read_text()).values():
                reg.register_fabric(FabricDescriptor.from_json(v))
        return reg
