"""Layout adaptors — the bus-virtualisation analogue (paper section 4.1.2).

A module's compiled interface fixes shapes/dtypes/shardings.  When a caller's
arrays differ (dtype, batch padding, host layout), an adaptor is instantiated
*only for that module* (the paper's "adaptor integrated into a module only if
needed") translating caller data to the slot's expected form and back, and
accounting the bytes it moves (Table-2 analogue).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class AdaptorReport:
    casts: int = 0
    pads: int = 0
    bytes_moved: int = 0
    identity: bool = True


def _leaf_bytes(x) -> int:
    return int(np.prod(x.shape)) * x.dtype.itemsize


def adapt_inputs(args: tuple, abstract_inputs: tuple
                 ) -> tuple[tuple, AdaptorReport]:
    """Coerce caller args to the module's abstract input signature."""
    rep = AdaptorReport()
    out = []
    for given, want_tree in zip(args, abstract_inputs):
        flat_g, treedef = jax.tree.flatten(given)
        flat_w = jax.tree.leaves(want_tree)
        new = []
        for g, w in zip(flat_g, flat_w):
            src_dtype = np.asarray(g).dtype if not hasattr(g, "dtype") \
                else g.dtype
            g = jnp.asarray(g)
            if src_dtype != w.dtype or g.dtype != w.dtype:
                g = g.astype(w.dtype)
                rep.casts += 1
                rep.identity = False
                rep.bytes_moved += _leaf_bytes(g)
            if g.shape != w.shape:
                assert len(g.shape) == len(w.shape), (g.shape, w.shape)
                assert all(gs <= ws for gs, ws in zip(g.shape, w.shape)), \
                    f"input {g.shape} exceeds module interface {w.shape}"
                pad = [(0, ws - gs) for gs, ws in zip(g.shape, w.shape)]
                g = jnp.pad(g, pad)
                rep.pads += 1
                rep.identity = False
                rep.bytes_moved += _leaf_bytes(g)
            new.append(g)
        out.append(jax.tree.unflatten(treedef, new))
    return tuple(out), rep


def strip_outputs(out, orig_batch: int | None):
    """Undo batch padding on the way back (best-effort, dim 0)."""
    if orig_batch is None:
        return out
    return jax.tree.map(
        lambda x: x[:orig_batch] if hasattr(x, "shape") and x.ndim >= 1
        else x, out)
