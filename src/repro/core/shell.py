"""FOS shell: the static partition of the compute fabric.

FPGA -> TPU mapping (DESIGN.md section 2): the *shell* is the host-side
runtime plus a geometry descriptor that splits a device mesh into
homogeneous, adjacent, mergeable *slots* (the PR-region analogue).  Slots
are congruent sub-meshes: an executable AOT-compiled against one slot's
interface re-binds to any congruent slot (module relocation), and adjacent
slots in the same adjacency group combine to host bigger implementation
alternatives (PR-region merging).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    """One reconfigurable region: a rectangle of the device grid."""
    name: str
    origin: tuple[int, int]        # (row, col) in the shell device grid
    shape: tuple[int, int]         # (rows, cols)
    group: str = "g0"              # adjacency group (mergeable within)

    def to_json(self) -> dict:
        return {"name": self.name, "origin": list(self.origin),
                "shape": list(self.shape), "group": self.group}

    @staticmethod
    def from_json(d: dict) -> "SlotSpec":
        return SlotSpec(d["name"], tuple(d["origin"]), tuple(d["shape"]),
                        d.get("group", "g0"))


@dataclasses.dataclass(frozen=True)
class ShellSpec:
    """Logical shell description (the paper's shell JSON, Listing 1).

    `speed` is the shell's relative clock (1.0 = the reference board):
    a chunk estimated at `est_chunk_ms` on the reference takes
    `est_chunk_ms / speed` here.  It feeds the fabric's heterogeneity-
    aware placement and the simulator's true chunk times.

    `ckpt` declares context-save/restore support (the PR-region
    readback capability checkpointing needs, core/checkpoint.py):
    a `ckpt=False` shell evicts lossily even when the fabric policy
    checkpoints, and checkpointed chunks never migrate onto it.
    """
    name: str
    grid: tuple[int, int]          # device grid (rows, cols)
    axes: tuple[str, str] = ("data", "model")
    slots: tuple[SlotSpec, ...] = ()
    version: str = "1"
    speed: float = 1.0             # relative clock (1.0 = reference)
    ckpt: bool = True              # context save/restore supported

    def to_json(self) -> dict:
        return {"name": self.name, "grid": list(self.grid),
                "axes": list(self.axes), "version": self.version,
                "speed": self.speed, "ckpt": self.ckpt,
                "regions": [s.to_json() for s in self.slots]}

    @staticmethod
    def from_json(d: dict) -> "ShellSpec":
        return ShellSpec(
            d["name"], tuple(d["grid"]), tuple(d.get("axes",
                                                     ("data", "model"))),
            tuple(SlotSpec.from_json(s) for s in d["regions"]),
            d.get("version", "1"), d.get("speed", 1.0),
            d.get("ckpt", True))

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def slot_shape(self) -> tuple[int, int]:
        shapes = {s.shape for s in self.slots}
        assert len(shapes) == 1, "slots must be homogeneous"
        # schedlint: ok(determinism) singleton set (asserted above):
        # there is no order to depend on
        return next(iter(shapes))

    def coverage(self) -> float:
        """Fraction of the grid covered by slots (Table-1 analogue)."""
        covered = sum(s.shape[0] * s.shape[1] for s in self.slots)
        return covered / (self.grid[0] * self.grid[1])

    def validate(self) -> None:
        grid = np.zeros(self.grid, dtype=int)
        for s in self.slots:
            r, c = s.origin
            h, w = s.shape
            assert r + h <= self.grid[0] and c + w <= self.grid[1], s
            grid[r:r + h, c:c + w] += 1
        assert (grid <= 1).all(), "slots overlap"


def uniform_shell(name: str, grid: tuple[int, int], n_slots: int,
                  axis: int = 1, speed: float = 1.0,
                  ckpt: bool = True) -> ShellSpec:
    """Split the grid into n homogeneous adjacent slots along `axis`."""
    assert grid[axis] % n_slots == 0
    slots = []
    for i in range(n_slots):
        if axis == 1:
            origin = (0, i * (grid[1] // n_slots))
            shape = (grid[0], grid[1] // n_slots)
        else:
            origin = (i * (grid[0] // n_slots), 0)
            shape = (grid[0] // n_slots, grid[1])
        slots.append(SlotSpec(f"slot{i}", origin, shape))
    spec = ShellSpec(name, grid, slots=tuple(slots), speed=speed,
                     ckpt=ckpt)
    spec.validate()
    return spec


# Pre-built shells (the paper ships ZCU102 / UltraZed / Ultra-96 shells).
def production_shells() -> dict[str, ShellSpec]:
    return {
        # one v5e pod, 4 slots of 64 chips
        "pod256_s4": uniform_shell("pod256_s4", (16, 16), 4),
        # one pod, 8 slots of 32 chips (finer-grained multi-tenancy)
        "pod256_s8": uniform_shell("pod256_s8", (16, 16), 8),
        # small "edge" shells for CPU-host execution benchmarks
        "host8_s4": uniform_shell("host8_s4", (1, 8), 4),
        "host8_s2": uniform_shell("host8_s2", (1, 8), 2),
        "host4_s4": uniform_shell("host4_s4", (1, 4), 4),
        # a previous-generation board at half the reference clock, for
        # heterogeneous fabrics (mixed board generations / edge+cloud)
        "host8_s4_lowclk": uniform_shell("host8_s4_lowclk", (1, 8), 4,
                                         speed=0.5),
    }


class Slot:
    """A slot bound to concrete devices."""

    def __init__(self, spec: SlotSpec, devices: np.ndarray,
                 axes: tuple[str, str]):
        self.spec = spec
        self.devices = devices                 # [rows, cols] device array
        self.axes = axes
        self._mesh = None

    @property
    def congruence_key(self) -> tuple:
        """Executables relocate freely between slots with equal keys."""
        return (self.spec.shape, self.axes)

    @property
    def mesh(self):
        import jax
        if self._mesh is None:
            self._mesh = jax.sharding.Mesh(self.devices, self.axes)
        return self._mesh

    def __repr__(self):
        return f"Slot({self.spec.name}, shape={self.spec.shape})"


class Shell:
    """ShellSpec bound to a real device grid ("loading the shell")."""

    def __init__(self, spec: ShellSpec, devices=None):
        import jax
        spec.validate()
        self.spec = spec
        if devices is None:
            devices = jax.devices()
        n = spec.grid[0] * spec.grid[1]
        assert len(devices) >= n, (len(devices), n)
        self.grid = np.array(devices[:n], dtype=object).reshape(spec.grid)
        self.slots = [
            Slot(s, self.grid[s.origin[0]:s.origin[0] + s.shape[0],
                              s.origin[1]:s.origin[1] + s.shape[1]],
                 spec.axes)
            for s in spec.slots
        ]

    def merged_slot(self, indices: list[int]) -> Slot:
        """Combine adjacent slots (same group, contiguous) into one."""
        specs = [self.spec.slots[i] for i in indices]
        assert len({s.group for s in specs}) == 1, "cross-group merge"
        specs = sorted(specs, key=lambda s: s.origin)
        rows = specs[0].shape[0]
        assert all(s.shape[0] == rows and s.origin[0] == specs[0].origin[0]
                   for s in specs), "merge only along the column axis"
        for a, b in zip(specs, specs[1:]):
            assert a.origin[1] + a.shape[1] == b.origin[1], \
                f"slots not adjacent: {a} {b}"
        origin = specs[0].origin
        width = sum(s.shape[1] for s in specs)
        merged = SlotSpec("+".join(s.name for s in specs), origin,
                          (rows, width), specs[0].group)
        devs = self.grid[origin[0]:origin[0] + rows,
                         origin[1]:origin[1] + width]
        return Slot(merged, devs, self.spec.axes)
