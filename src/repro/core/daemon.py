"""FOS daemon: multi-tenant acceleration service (paper section 4.4.1).

The paper uses gRPC + shared memory; in this single-host container the
daemon is in-process with a serialisable request boundary (a real RPC
front-end bolts onto `submit` unchanged) and zero-copy array handoff.

Execution model: the daemon is a thin executor over a `Fabric` — a named
collection of shells, each with its own `SchedulerState`, behind one
scheduling contract (core/fabric.py).  A scheduler thread drives
`Fabric.schedule` on every event; each (shell, assignment) runs on its
shell's slots through one shared worker pool (XLA dispatch is
per-device-set, so distinct slots execute concurrently).  Construct with
a single `Shell` for the seed single-shell behavior, or with a
`{name: Shell}` mapping for multi-shell execution with locality-aware
placement and cross-shell work stealing.

Preemption (PolicyConfig.preemptive): when the policy evicts an in-flight
chunk, the daemon cancels the victim assignment — if its worker has not
started, it is skipped outright; if it is mid-dispatch, its result is
discarded on completion (the FPGA analogue: reconfiguring a PR region
kills the resident accelerator's partial work).  Either way the scheduler
has already requeued the chunk, so it re-runs under a fresh assignment and
the request's future still resolves with every chunk exactly once.

Checkpointing (PolicyConfig.ckpt): the daemon mirrors the scheduling
contract on its wall-clock path — evictions record wall-clock progress
estimates, resumed assignments are priced at their remaining fraction
plus restore, checkpointed chunks migrate across live shells with their
records, and `daemon.ckpt_stats` surfaces the saves/restores/migrations
counters.  The physical analogue stops at the model boundary: an
in-process XLA computation cannot restore partial context, so a resumed
chunk re-runs in full (a real FPGA backend would read back and restore
the PR region state); the scheduler's decisions and accounting are
checkpoint-aware either way.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax

from repro.core import bus
from repro.core.fabric import Fabric
from repro.core.module import AccelModule, Placement, run_placement
from repro.core.registry import Registry
from repro.core.scheduler import Assignment, PolicyConfig, SchedulerState
from repro.core.shell import Shell
from repro.core.slo import AdmissionRejected, QoSContract


def _now_ms() -> float:
    """Scheduler clock: milliseconds (matches the cost model's units).

    Every daemon timestamp — `JobHandle.t_submit` included — uses this
    clock, so handle and scheduler times subtract directly.
    """
    return time.perf_counter() * 1e3


@dataclasses.dataclass
class JobHandle:
    rid: int
    future: Future          # resolves to list of chunk outputs
    t_submit: float         # _now_ms() — same clock as the scheduler
    priority: int = 0
    deadline_ms: float | None = None


class Daemon:
    def __init__(self, shell, registry: Registry,
                 policy: PolicyConfig | None = None, max_workers: int = 8,
                 obs=None):
        """`shell`: a `Shell` (single-shell, seed behavior) or an ordered
        `{name: Shell}` mapping (multi-shell fabric).

        `obs`: an optional `repro.obs.FlightRecorder` to attach to the
        fabric (duck-typed — the daemon never imports repro.obs).  Its
        event timestamps then run on the daemon's wall clock."""
        if isinstance(shell, dict):
            self.shells: dict[str, Shell] = dict(shell)
        else:
            self.shells = {shell.spec.name: shell}
        self.shell = next(iter(self.shells.values()))
        self.registry = registry
        # the ShellSpec carries the shell's slot count AND its relative
        # speed, so a heterogeneous {name: Shell} fabric gets
        # speed-aware placement for free
        self.fabric = Fabric(
            {name: s.spec for name, s in self.shells.items()},
            registry, policy)
        if obs is not None:
            obs.attach(self.fabric)
        self._modules: dict[str, AccelModule] = {}
        self._placements: dict[tuple[str, int, int], Placement] = {}
        self._events: queue.Queue = queue.Queue()
        # reentrant: `metrics` (and its ckpt_stats/slo_stats/
        # reserve_history aliases) snapshots under this lock, and
        # callers driving the scheduler state directly may already
        # hold it when they read stats
        self._lock = threading.RLock()
        self._results: dict[int, list] = {}
        self._handles: dict[int, JobHandle] = {}
        self._cancelled: set[int] = set()     # aids of preempted assignments
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"reconfigurations": 0, "reuses": 0, "chunks": 0,
                      "preemptions": 0, "sched_ns": 0, "sched_calls": 0}
        self._thread.start()

    @property
    def state(self) -> SchedulerState:
        """The first shell's scheduler state (the whole story for the
        degenerate single-shell daemon; one shard of a bigger fabric)."""
        return next(iter(self.fabric.states.values()))

    @property
    def policy(self) -> PolicyConfig:
        return self.fabric.policy

    @property
    def metrics(self) -> dict:
        """The daemon's one metrics surface, snapshotted under the
        scheduler lock so every block is from the same instant:

        - ``daemon``: executor counters (reconfigurations, reuses,
          chunks, preemptions, scheduling-pass timing);
        - ``ckpt``: checkpoint counters when `PolicyConfig.ckpt` is on;
        - ``slo``: per-tenant SLO attainment once any `QoSContract` is
          registered;
        - ``reserve_history``: per-shell effective-reservation trace
          `[(t_ms, slots), ...]` recorded on change;
        - ``obs``: the `FlightRecorder.snapshot()` payload when a
          recorder was passed at construction (absent otherwise).

        `ckpt_stats`/`slo_stats`/`reserve_history` are thin aliases of
        the corresponding blocks."""
        with self._lock:
            fab = self.fabric
            m = {
                "daemon": dict(self.stats),
                "ckpt": (dict(fab.ckpt.stats)
                         if fab.ckpt is not None else {}),
                "slo": (fab.slo.attainment()
                        if fab.slo is not None else {}),
                "reserve_history": {
                    name: list(st.reserve_history)
                    for name, st in fab.states.items()},
            }
            if fab.obs is not None:
                m["obs"] = fab.obs.snapshot()
            return m

    @property
    def ckpt_stats(self) -> dict:
        """Checkpoint counters (saves/restores/migrations/dropped) when
        `PolicyConfig.ckpt` is on; `{}` otherwise.  Thin alias of
        ``metrics["ckpt"]``."""
        return self.metrics["ckpt"]

    @property
    def slo_stats(self) -> dict:
        """Per-tenant SLO attainment snapshot (verdict counts,
        deadline-hit fraction, attainment history) once any
        `QoSContract` is registered; `{}` otherwise.  Thin alias of
        ``metrics["slo"]``."""
        return self.metrics["slo"]

    def register_contract(self, contract: QoSContract) -> None:
        """Attach a tenant's `QoSContract` to the fabric; every
        subsequent `submit` is screened by admission control.  Unknown
        degraded-module names raise the registry's rich KeyError."""
        with self._lock:
            self.fabric.register_contract(contract, now=_now_ms())

    @property
    def reserve_history(self) -> dict[str, list]:
        """Per-shell effective-reservation trace `[(t_ms, slots), ...]`
        recorded on change — the adaptive reservation's sizing decisions
        (`PolicyConfig.reserve_mode == "adaptive"`, fed from the wall
        clock at `submit`); static mode records its constant once.
        Thin alias of ``metrics["reserve_history"]``."""
        return self.metrics["reserve_history"]

    # -- public API (paper Listings 4/5) --------------------------------------

    def run(self, tenant: str, jobs: list[dict]) -> list[JobHandle]:
        """jobs: [{"name": <module>, "chunks": [args...],
                   "priority"?: int, "deadline_ms"?: float,
                   "affinity"?: <shell name>}] -> handles."""
        handles = []
        for j in jobs:
            handles.append(self.submit(tenant, j["name"], j["chunks"],
                                       priority=j.get("priority", 0),
                                       deadline_ms=j.get("deadline_ms"),
                                       affinity=j.get("affinity"),
                                       contract=j.get("contract")))
        return handles

    def submit(self, tenant: str, module: str, chunks: list,
               priority: int = 0, deadline_ms: float | None = None,
               affinity: str | None = None,
               contract: QoSContract | None = None) -> JobHandle:
        """Submit one job.  `contract` registers (or refreshes) the
        tenant's `QoSContract` before admission screening; when the
        fabric carries any contract, a rejected submit still returns a
        handle, but its future fails with `AdmissionRejected` carrying
        the structured verdict (the predicted contract violation)."""
        fut: Future = Future()
        with self._lock:
            now = _now_ms()
            # fabric.submit validates module/affinity (raising before
            # any state is created) and copies the chunk list
            job = self.fabric.submit(tenant, module, chunks,
                                     now=now, priority=priority,
                                     deadline_ms=deadline_ms,
                                     affinity=affinity,
                                     contract=contract)
            h = JobHandle(job.gid, fut, now,
                          priority=priority, deadline_ms=deadline_ms)
            if job.rejected:
                # shed at admission: no chunks, no results buffer, no
                # registered handle — only the failed future remains
                fut.set_exception(AdmissionRejected(job.verdict))
                return h
            self._results[job.gid] = [None] * job.n_chunks
            self._handles[job.gid] = h
        self._events.put(("submit", None))
        return h

    def shutdown(self):
        self._stop.set()
        self._events.put(("stop", None))
        self._thread.join(timeout=10)
        self._pool.shutdown(wait=True)

    # -- module management -----------------------------------------------------

    def _module(self, name: str) -> AccelModule:
        with self._lock:
            mod = self._modules.get(name)
        if mod is None:
            desc = self.registry.module(name)
            builder = desc.load_builder()
            mod = AccelModule(name, builder, desc.footprints)
            with self._lock:
                mod = self._modules.setdefault(name, mod)
        return mod

    def _placement(self, shell_name: str, a: Assignment) -> Placement:
        key = (shell_name, a.rng.start, a.rng.size)
        with self._lock:
            pl = self._placements.get(key)
            if pl is not None and pl.module.name == a.module \
                    and not a.reconfigure:
                self.stats["reuses"] += 1
                return pl
        mod = self._module(a.module)
        shell = self.shells[shell_name]
        slot = (shell.slots[a.rng.start] if a.rng.size == 1 else
                shell.merged_slot(list(a.rng.slots)))
        pl = mod.place(slot, a.footprint)
        with self._lock:
            # a preempted victim mid-dispatch must not clobber the
            # placement its preemptor just installed on the same range
            if a.aid in self.fabric.states[shell_name].active:
                self._placements[key] = pl
                self.stats["reconfigurations"] += 1
        return pl

    # -- event loop -------------------------------------------------------------

    def _loop(self):
        while not self._stop.is_set():
            try:
                self._events.get(timeout=0.1)
            except queue.Empty:
                continue
            # drain
            try:
                while True:
                    self._events.get_nowait()
            except queue.Empty:
                pass
            with self._lock:
                t0 = time.perf_counter_ns()
                if self.fabric.network.active:
                    # mirror the simulator's "net" release events on
                    # wall clock: expired link occupancy frees before
                    # the pass, so backed-off steal estimates recover
                    now_ms = _now_ms()
                    for xfer in self.fabric.network.advance(now_ms):
                        if self.fabric.obs is not None:
                            self.fabric.obs.on_transfer_complete(
                                xfer.src, xfer.dst, now_ms)
                    self.fabric.network.drain_releases()
                assignments = self.fabric.schedule(now=_now_ms())
                # the daemon keys no per-chunk executor state to stolen
                # identities (payloads move by reference); drain the
                # retirement log so it cannot grow for a long-lived
                # daemon under heavy stealing
                self.fabric.drain_moved()
                self._handle_preempted_locked()
                self.stats["sched_ns"] += time.perf_counter_ns() - t0
                self.stats["sched_calls"] += 1
            for shell_name, a in assignments:
                self._pool.submit(self._run_assignment, shell_name, a)

    def _gid_of_locked(self, shell_name: str, rid: int) -> int:
        """Job id for a sub-request; requests created directly on a shell
        state (the legacy single-shell path) map to their own rid."""
        entry = self.fabric.sub(shell_name, rid)
        return entry[0].gid if entry is not None else rid

    def _handle_preempted_locked(self) -> None:
        for shell_name, v in self.fabric.drain_preempted():
            self._cancelled.add(v.aid)
            self.stats["preemptions"] += 1
            # a failed request whose last in-flight chunk was evicted
            # drains here rather than through complete()
            self._finalize_locked(self._gid_of_locked(shell_name, v.rid))

    def _finalize_locked(self, gid: int) -> None:
        """Release per-job state once a job has fully drained."""
        job = self.fabric.jobs.get(gid)
        if job is not None:
            if not self.fabric.finished(gid):
                return
            self._handles.pop(gid, None)
            self._results.pop(gid, None)
            # keep the job/request records (stats/queries) but release
            # the input arrays — a long-running daemon must not
            # accumulate every tenant's payloads
            job.payloads = None
            for shell_name, rid in job.subs:
                self.fabric.states[shell_name].requests[rid].payloads = None
            return
        # legacy path: the request was created directly on a shell state
        for st in self.fabric.states.values():
            req = st.requests.get(gid)
            if req is not None:
                if req.finished:
                    self._handles.pop(gid, None)
                    self._results.pop(gid, None)
                    req.payloads = None
                return

    def _run_assignment(self, shell_name: str, a: Assignment):
        with self._lock:
            if a.aid in self._cancelled:   # preempted before we started
                self._cancelled.discard(a.aid)
                self._finalize_locked(self._gid_of_locked(shell_name, a.rid))
                self._events.put(("cancelled", None))
                return
        st = self.fabric.states[shell_name]
        try:
            pl = self._placement(shell_name, a)
            req = st.requests[a.rid]
            payload = req.payloads[a.chunk]
            prog = pl.module.program(pl.slot, pl.footprint)
            args, _ = bus.adapt_inputs(
                payload if isinstance(payload, tuple) else (payload,),
                prog.abstract_inputs)
            t_run = _now_ms()
            out = run_placement(pl, *args)
            t_run = _now_ms() - t_run
            err = None
        except Exception as e:  # noqa: BLE001 - propagate to the future
            out, err, t_run = None, e, 0.0
        with self._lock:
            self._cancelled.discard(a.aid)
            entry = self.fabric.sub(shell_name, a.rid)
            if not self.fabric.complete(shell_name, a, now=_now_ms()):
                # preempted mid-dispatch: discard the partial result; the
                # chunk was requeued and re-runs under a fresh assignment
                self._finalize_locked(self._gid_of_locked(shell_name, a.rid))
                self._events.put(("discarded", None))
                return
            self.stats["chunks"] += 1
            if err is None and self.policy.refine_cost_model:
                # reconfigured chunks refine too — an always-
                # reconfiguring module must not keep a stale estimate
                # forever.  t_run wraps run_placement only, so unlike
                # the simulator's elapsed time it never contains the
                # reconfiguration cost (placement/compile happen before
                # the clock starts) and nothing is subtracted here.
                # Resumed chunks (a.frac < 1) re-run in full in-process,
                # so t_run is already a full-chunk observation — no
                # frac scaling either (unlike the simulator).
                self.fabric.cost.observe(a.module, a.footprint,
                                         max(1e-3, t_run),
                                         self.fabric.speeds[shell_name])
            if entry is not None:
                job, cmap = entry
                gid, gchunk = job.gid, cmap[a.chunk]
                complete = job.complete
            else:                           # legacy direct-state request
                job = None
                gid, gchunk = a.rid, a.chunk
                complete = st.requests[a.rid].complete
            h = self._handles.get(gid)
            if err is not None:
                # abort the rest of the job on every shell and surface the
                # error once; drop per-job buffers so a failing chunk
                # leaves no orphaned state behind
                if job is not None:
                    self.fabric.abort(gid)
                else:
                    st.abort(a.rid)
                self._results.pop(gid, None)
                if h is not None and not h.future.done():
                    h.future.set_exception(err)
            else:
                buf = self._results.get(gid)
                if buf is not None:
                    buf[gchunk] = out
                if complete and h is not None and not h.future.done():
                    h.future.set_result(self._results.pop(gid))
            self._finalize_locked(gid)
        self._events.put(("done", None))
