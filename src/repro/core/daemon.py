"""FOS daemon: multi-tenant acceleration service (paper section 4.4.1).

The paper uses gRPC + shared memory; in this single-host container the
daemon is in-process with a serialisable request boundary (a real RPC
front-end bolts onto `submit` unchanged) and zero-copy array handoff.

Execution model: a scheduler thread applies the resource-elastic policy on
every event; each assignment runs on its slot through a worker pool (XLA
dispatch is per-device-set, so distinct slots execute concurrently).

Preemption (PolicyConfig.preemptive): when the policy evicts an in-flight
chunk, the daemon cancels the victim assignment — if its worker has not
started, it is skipped outright; if it is mid-dispatch, its result is
discarded on completion (the FPGA analogue: reconfiguring a PR region
kills the resident accelerator's partial work).  Either way the scheduler
has already requeued the chunk, so it re-runs under a fresh assignment and
the request's future still resolves with every chunk exactly once.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax

from repro.core import bus
from repro.core.module import AccelModule, Placement, run_placement
from repro.core.registry import Registry
from repro.core.scheduler import Assignment, PolicyConfig, SchedulerState
from repro.core.shell import Shell


def _now_ms() -> float:
    """Scheduler clock: milliseconds (matches the cost model's units)."""
    return time.perf_counter() * 1e3


@dataclasses.dataclass
class JobHandle:
    rid: int
    future: Future          # resolves to list of chunk outputs
    t_submit: float
    priority: int = 0
    deadline_ms: float | None = None


class Daemon:
    def __init__(self, shell: Shell, registry: Registry,
                 policy: PolicyConfig | None = None, max_workers: int = 8):
        self.shell = shell
        self.registry = registry
        self.state = SchedulerState(len(shell.slots), registry, policy)
        self._modules: dict[str, AccelModule] = {}
        self._placements: dict[tuple[int, int], Placement] = {}
        self._events: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._results: dict[int, list] = {}
        self._handles: dict[int, JobHandle] = {}
        self._cancelled: set[int] = set()     # aids of preempted assignments
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"reconfigurations": 0, "reuses": 0, "chunks": 0,
                      "preemptions": 0, "sched_ns": 0, "sched_calls": 0}
        self._thread.start()

    # -- public API (paper Listings 4/5) --------------------------------------

    def run(self, tenant: str, jobs: list[dict]) -> list[JobHandle]:
        """jobs: [{"name": <module>, "chunks": [args...],
                   "priority"?: int, "deadline_ms"?: float}] -> handles."""
        handles = []
        for j in jobs:
            handles.append(self.submit(tenant, j["name"], j["chunks"],
                                       priority=j.get("priority", 0),
                                       deadline_ms=j.get("deadline_ms")))
        return handles

    def submit(self, tenant: str, module: str, chunks: list,
               priority: int = 0,
               deadline_ms: float | None = None) -> JobHandle:
        self.registry.module(module)   # validates
        fut: Future = Future()
        with self._lock:
            req = self.state.submit(tenant, module, len(chunks),
                                    payloads=list(chunks), now=_now_ms(),
                                    priority=priority,
                                    deadline_ms=deadline_ms)
            self._results[req.rid] = [None] * len(chunks)
            h = JobHandle(req.rid, fut, time.perf_counter(),
                          priority=priority, deadline_ms=deadline_ms)
            self._handles[req.rid] = h
        self._events.put(("submit", None))
        return h

    def shutdown(self):
        self._stop.set()
        self._events.put(("stop", None))
        self._thread.join(timeout=10)
        self._pool.shutdown(wait=True)

    # -- module management -----------------------------------------------------

    def _module(self, name: str) -> AccelModule:
        with self._lock:
            mod = self._modules.get(name)
        if mod is None:
            desc = self.registry.module(name)
            builder = desc.load_builder()
            mod = AccelModule(name, builder, desc.footprints)
            with self._lock:
                mod = self._modules.setdefault(name, mod)
        return mod

    def _placement(self, a: Assignment) -> Placement:
        key = (a.rng.start, a.rng.size)
        with self._lock:
            pl = self._placements.get(key)
            if pl is not None and pl.module.name == a.module \
                    and not a.reconfigure:
                self.stats["reuses"] += 1
                return pl
        mod = self._module(a.module)
        slot = (self.shell.slots[a.rng.start] if a.rng.size == 1 else
                self.shell.merged_slot(list(a.rng.slots)))
        pl = mod.place(slot, a.footprint)
        with self._lock:
            # a preempted victim mid-dispatch must not clobber the
            # placement its preemptor just installed on the same range
            if a.aid in self.state.active:
                self._placements[key] = pl
                self.stats["reconfigurations"] += 1
        return pl

    # -- event loop -------------------------------------------------------------

    def _loop(self):
        while not self._stop.is_set():
            try:
                self._events.get(timeout=0.1)
            except queue.Empty:
                continue
            # drain
            try:
                while True:
                    self._events.get_nowait()
            except queue.Empty:
                pass
            with self._lock:
                t0 = time.perf_counter_ns()
                assignments = self.state.schedule(now=_now_ms())
                self._handle_preempted_locked()
                self.stats["sched_ns"] += time.perf_counter_ns() - t0
                self.stats["sched_calls"] += 1
            for a in assignments:
                self._pool.submit(self._run_assignment, a)

    def _handle_preempted_locked(self) -> None:
        for v in self.state.drain_preempted():
            self._cancelled.add(v.aid)
            self.stats["preemptions"] += 1
            # a failed request whose last in-flight chunk was evicted
            # drains here rather than through complete()
            self._finalize_locked(v.rid)

    def _finalize_locked(self, rid: int) -> None:
        """Release per-request state once a request has fully drained."""
        req = self.state.requests.get(rid)
        if req is None or not req.finished:
            return
        self._handles.pop(rid, None)
        self._results.pop(rid, None)
        # keep the Request record (stats/queries) but release the input
        # arrays — a long-running daemon must not accumulate every
        # tenant's payloads
        req.payloads = None

    def _run_assignment(self, a: Assignment):
        with self._lock:
            if a.aid in self._cancelled:   # preempted before we started
                self._cancelled.discard(a.aid)
                self._finalize_locked(a.rid)
                self._events.put(("cancelled", None))
                return
        try:
            pl = self._placement(a)
            req = self.state.requests[a.rid]
            payload = req.payloads[a.chunk]
            prog = pl.module.program(pl.slot, pl.footprint)
            args, _ = bus.adapt_inputs(
                payload if isinstance(payload, tuple) else (payload,),
                prog.abstract_inputs)
            out = run_placement(pl, *args)
            err = None
        except Exception as e:  # noqa: BLE001 - propagate to the future
            out, err = None, e
        with self._lock:
            self._cancelled.discard(a.aid)
            if not self.state.complete(a, now=_now_ms()):
                # preempted mid-dispatch: discard the partial result; the
                # chunk was requeued and re-runs under a fresh assignment
                self._finalize_locked(a.rid)
                self._events.put(("discarded", None))
                return
            self.stats["chunks"] += 1
            req = self.state.requests[a.rid]
            h = self._handles.get(a.rid)
            if err is not None:
                # abort the rest of the request and surface the error once;
                # drop per-request buffers so a failing chunk leaves no
                # orphaned state behind
                self.state.abort(a.rid)
                self._results.pop(a.rid, None)
                if h is not None and not h.future.done():
                    h.future.set_exception(err)
            else:
                buf = self._results.get(a.rid)
                if buf is not None:
                    buf[a.chunk] = out
                if req.complete and h is not None and not h.future.done():
                    h.future.set_result(self._results.pop(a.rid))
            self._finalize_locked(a.rid)
        self._events.put(("done", None))
