"""FOS daemon: multi-tenant acceleration service (paper section 4.4.1).

The paper uses gRPC + shared memory; in this single-host container the
daemon is in-process with a serialisable request boundary (a real RPC
front-end bolts onto `submit` unchanged) and zero-copy array handoff.

Execution model: a scheduler thread applies the resource-elastic policy on
every event; each assignment runs on its slot through a worker pool (XLA
dispatch is per-device-set, so distinct slots execute concurrently).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax

from repro.core import bus
from repro.core.module import AccelModule, Placement, run_placement
from repro.core.registry import Registry
from repro.core.scheduler import Assignment, PolicyConfig, SchedulerState
from repro.core.shell import Shell


@dataclasses.dataclass
class JobHandle:
    rid: int
    future: Future          # resolves to list of chunk outputs
    t_submit: float


class Daemon:
    def __init__(self, shell: Shell, registry: Registry,
                 policy: PolicyConfig | None = None, max_workers: int = 8):
        self.shell = shell
        self.registry = registry
        self.state = SchedulerState(len(shell.slots), registry, policy)
        self._modules: dict[str, AccelModule] = {}
        self._placements: dict[tuple[int, int], Placement] = {}
        self._events: queue.Queue = queue.Queue()
        self._lock = threading.Lock()
        self._results: dict[int, list] = {}
        self._handles: dict[int, JobHandle] = {}
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self.stats = {"reconfigurations": 0, "reuses": 0, "chunks": 0,
                      "sched_ns": 0, "sched_calls": 0}
        self._thread.start()

    # -- public API (paper Listings 4/5) --------------------------------------

    def run(self, tenant: str, jobs: list[dict]) -> list[JobHandle]:
        """jobs: [{"name": <module>, "chunks": [args...]}] -> handles."""
        handles = []
        for j in jobs:
            handles.append(self.submit(tenant, j["name"], j["chunks"]))
        return handles

    def submit(self, tenant: str, module: str, chunks: list) -> JobHandle:
        self.registry.module(module)   # validates
        fut: Future = Future()
        with self._lock:
            req = self.state.submit(tenant, module, len(chunks),
                                    payloads=list(chunks),
                                    now=time.perf_counter())
            self._results[req.rid] = [None] * len(chunks)
            h = JobHandle(req.rid, fut, time.perf_counter())
            self._handles[req.rid] = h
        self._events.put(("submit", None))
        return h

    def shutdown(self):
        self._stop.set()
        self._events.put(("stop", None))
        self._thread.join(timeout=10)
        self._pool.shutdown(wait=True)

    # -- module management -----------------------------------------------------

    def _module(self, name: str) -> AccelModule:
        if name not in self._modules:
            desc = self.registry.module(name)
            builder = desc.load_builder()
            self._modules[name] = AccelModule(name, builder,
                                              desc.footprints)
        return self._modules[name]

    def _placement(self, a: Assignment) -> Placement:
        key = (a.rng.start, a.rng.size)
        pl = self._placements.get(key)
        if pl is not None and pl.module.name == a.module and not a.reconfigure:
            self.stats["reuses"] += 1
            return pl
        mod = self._module(a.module)
        slot = (self.shell.slots[a.rng.start] if a.rng.size == 1 else
                self.shell.merged_slot(list(a.rng.slots)))
        pl = mod.place(slot, a.footprint)
        self._placements[key] = pl
        self.stats["reconfigurations"] += 1
        return pl

    # -- event loop -------------------------------------------------------------

    def _loop(self):
        while not self._stop.is_set():
            try:
                self._events.get(timeout=0.1)
            except queue.Empty:
                continue
            # drain
            try:
                while True:
                    self._events.get_nowait()
            except queue.Empty:
                pass
            with self._lock:
                t0 = time.perf_counter_ns()
                assignments = self.state.schedule()
                self.stats["sched_ns"] += time.perf_counter_ns() - t0
                self.stats["sched_calls"] += 1
            for a in assignments:
                self._pool.submit(self._run_assignment, a)

    def _run_assignment(self, a: Assignment):
        try:
            pl = self._placement(a)
            req = self.state.requests[a.rid]
            payload = req.payloads[a.chunk]
            prog = pl.module.program(pl.slot, pl.footprint)
            args, _ = bus.adapt_inputs(
                payload if isinstance(payload, tuple) else (payload,),
                prog.abstract_inputs)
            out = run_placement(pl, *args)
            err = None
        except Exception as e:  # noqa: BLE001 - propagate to the future
            out, err = None, e
        with self._lock:
            self.stats["chunks"] += 1
            self.state.complete(a, now=time.perf_counter())
            req = self.state.requests[a.rid]
            if err is None:
                self._results[a.rid][a.chunk] = out
            h = self._handles[a.rid]
            if err is not None and not h.future.done():
                h.future.set_exception(err)
            elif req.complete and not h.future.done():
                h.future.set_result(self._results.pop(a.rid))
        self._events.put(("done", None))
