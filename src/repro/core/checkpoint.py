"""Checkpoint/restore for preempted chunks (context-save analogue).

The preemption model of the scheduler core is lossy by default: an
evicted chunk is requeued at zero progress and re-runs from scratch, so
under an aggressive interactive stream a large fraction of slot-time is
discarded as evicted partial work (~26% at 10 ms inter-arrival in the
THEMIS-style benchmark).  Rodriguez-Canal et al. (2022) show FPGA
context-save/restore makes preemption near-free at task granularity;
THEMIS (Karabulut et al., 2024) motivates pricing the save/restore cost
inside the fairness loop instead of assuming it away.  This module is
that cost model:

  - a `ChunkCheckpoint` records an evicted chunk's *progress fraction*
    (plus module, footprint, shell of origin) at the instant the
    scheduler evicts it;
  - a `CheckpointManager` owns the records — one per (rid, chunk),
    consumed when the chunk is re-issued — and prices the modeled
    context-save and context-restore costs: `PolicyConfig.ckpt_save_ms`
    / `ckpt_restore_ms` by default, overridden per implementation
    alternative by `ImplAlt.meta["ckpt_save_ms"]` /
    `meta["ckpt_restore_ms"]`, and speed-scaled like chunk times
    (context movement runs through the shell's own fabric, unlike the
    generation-independent configuration port).

Progress is estimated from the scheduler's cost model: the fraction of
the chunk's estimated service time that elapsed after the run's own
overheads (restore, save, reconfiguration).  It is a *model* — the
simulator realizes it exactly when `est_chunk_ms` matches the true
chunk time, and the live daemon uses it as a wall-clock estimate (an
in-process XLA computation cannot restore partial context, so the
daemon re-runs resumed chunks in full while keeping the same scheduling
contract and accounting).

One manager is shared by every `SchedulerState` in a `Fabric` (like the
`CostModel`), keyed by (rid, chunk) — rids are fabric-unique — so a
checkpointed chunk can *migrate*: when work stealing moves it to
another shell, the fabric re-keys its record (`rekey`) and the thief
resumes it there, paying restore + transfer instead of re-running from
zero.  A shell without context-readback support (`ShellSpec.ckpt =
False`) never saves, and a record migrated onto it is dropped.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class ChunkCheckpoint:
    """Saved context of one preempted chunk: how far it got, where."""
    rid: int
    chunk: int
    module: str
    footprint: int                 # footprint at save time (informational:
    #                                progress is implementation-portable —
    #                                work-items done, not bitstream state)
    progress: float                # fraction of the chunk's compute done
    shell: str | None = None       # shell of origin (None: bare state)
    t_saved: float = 0.0

    @property
    def remaining(self) -> float:
        return max(0.0, 1.0 - self.progress)


# schedlint (analysis/mutation.py): checkpoint records carry no version
# of their own — they piggyback on the owning shell's `_version`.  That
# is sound only if every call to one of these mutators sits on an
# execution path that also bumps a shell version; the mutation checker
# enforces exactly that, which is what lets memo keys treat
# `_recs`/`_rid_progress` reads as covered by the "state" token.
CKPT_MUTATORS = ("save", "take", "rekey", "drop_request")


class CheckpointManager:
    """Owns `ChunkCheckpoint` records and prices save/restore.

    The scheduler calls `save` when it evicts an assignment (recording
    progress, returning the priced save cost) and `take` when it
    re-issues the chunk (consuming the record; the resumed assignment
    runs only the remaining fraction plus the restore cost).  A fabric
    calls `rekey` when stealing moves a checkpointed chunk across
    shells, and `drop_request` when a request is aborted.
    """

    def __init__(self, registry, policy):
        self.registry = registry
        self.policy = policy
        self._recs: dict[tuple[int, int], ChunkCheckpoint] = {}
        # per-rid summed progress of recorded chunks, kept in sync with
        # _recs so the hot backlog estimator reads it in O(1)
        self._rid_progress: dict[int, float] = {}
        self.stats = {"saves": 0, "restores": 0, "migrations": 0,
                      "dropped": 0}

    def __len__(self) -> int:
        return len(self._recs)

    # -- cost model -----------------------------------------------------------

    def _cost_ms(self, module: str, footprint: int, key: str,
                 default: float, speed: float) -> float:
        impl = self.registry.module(module).impl_for(footprint)
        v = default if impl is None else impl.meta.get(key, default)
        return float(v) / speed

    def save_cost_ms(self, module: str, footprint: int,
                     speed: float = 1.0) -> float:
        return self._cost_ms(module, footprint, "ckpt_save_ms",
                             self.policy.ckpt_save_ms, speed)

    def restore_cost_ms(self, module: str, footprint: int,
                        speed: float = 1.0) -> float:
        return self._cost_ms(module, footprint, "ckpt_restore_ms",
                             self.policy.ckpt_restore_ms, speed)

    # -- record lifecycle -----------------------------------------------------

    def save(self, a, now: float, est_full_ms: float,
             speed: float = 1.0, shell: str | None = None,
             extra_overhead_ms: float = 0.0) -> float:
        """Record an evicted assignment's progress; return the priced
        context-save cost the eviction must realize.

        Progress this run = time elapsed since placement minus the
        run's own overheads (restore, save, reconfiguration, plus any
        `extra_overhead_ms` the caller knows about — a fabric passes
        the stolen chunk's transfer cost), as a fraction of the
        full-chunk estimate, on top of whatever prior progress the
        assignment resumed from (`1 - a.frac`).  When the run made no
        new progress (evicted mid-overhead) the prior context is still
        on record but nothing new needs saving, so the returned cost is
        0.0; when there is no progress at all, no record is created.
        """
        prior = max(0.0, 1.0 - a.frac)
        overhead = a.restore_ms + a.save_ms + extra_overhead_ms
        if a.reconfigure:
            overhead += self.policy.reconfig_penalty_ms
        run_ms = max(0.0, (now - a.t_start) - overhead)
        delta = min(a.frac, run_ms / max(est_full_ms, 1e-9))
        progress = min(1.0, prior + delta)
        if progress <= 0.0:
            return 0.0
        self._recs[(a.rid, a.chunk)] = ChunkCheckpoint(
            a.rid, a.chunk, a.module, a.footprint, progress,
            shell=shell, t_saved=now)
        self._rid_progress[a.rid] = \
            self._rid_progress.get(a.rid, 0.0) + progress
        if delta <= 0.0:
            return 0.0                 # prior context already saved
        self.stats["saves"] += 1
        return self.save_cost_ms(a.module, a.footprint, speed)

    def take(self, rid: int, chunk: int) -> ChunkCheckpoint | None:
        """Consume the record at re-issue (the chunk is being resumed)."""
        rec = self._recs.pop((rid, chunk), None)
        if rec is not None:
            self._drop_progress(rid, rec.progress)
            self.stats["restores"] += 1
        return rec

    def _drop_progress(self, rid: int, progress: float) -> None:
        v = self._rid_progress.get(rid, 0.0) - progress
        if v <= 1e-12:
            self._rid_progress.pop(rid, None)
        else:
            self._rid_progress[rid] = v

    def peek(self, rid: int, chunk: int) -> ChunkCheckpoint | None:
        return self._recs.get((rid, chunk))

    def rekey(self, old: tuple[int, int], new: tuple[int, int],
              shell: str | None = None, capable: bool = True) -> bool:
        """Move a record to a stolen chunk's new (rid, chunk) identity.
        A thief shell without context-restore support drops the record
        instead (the chunk re-runs from zero there).  Returns True when
        a record migrated."""
        rec = self._recs.pop(old, None)
        if rec is None:
            return False
        self._drop_progress(old[0], rec.progress)
        if not capable:
            self.stats["dropped"] += 1
            return False
        rec.rid, rec.chunk = new
        rec.shell = shell
        self._recs[new] = rec
        self._rid_progress[new[0]] = \
            self._rid_progress.get(new[0], 0.0) + rec.progress
        self.stats["migrations"] += 1
        return True

    def drop_request(self, rid: int) -> None:
        """Release every record of an aborted request."""
        for key in [k for k in self._recs if k[0] == rid]:
            del self._recs[key]
        self._rid_progress.pop(rid, None)

    def pending_progress(self, rid: int) -> float:
        """Summed progress fractions of a request's checkpointed pending
        chunks — the backlog estimator subtracts this so a shell with
        mostly-done victims looks as short as it really is.  O(1): kept
        in sync with the record map."""
        return self._rid_progress.get(rid, 0.0)
