"""Buddy allocator over shell slots.

Implements the paper's "combine adjacent PR regions" capability: allocations
are power-of-two runs of adjacent slots, aligned buddy-style so merges are
always possible when both buddies are free.  O(slots) per operation.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Range:
    start: int
    size: int

    @property
    def slots(self) -> tuple[int, ...]:
        return tuple(range(self.start, self.start + self.size))


class BuddyAllocator:
    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n = n_slots            # any count; allocations stay
        self.busy: set[int] = set()  # power-of-two sized & size-aligned

    # -- queries ------------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i in range(self.n) if i not in self.busy]

    def can_alloc(self, size: int, within: int | None = None) -> bool:
        return self.find(size, within) is not None

    def largest_free(self) -> int:
        size = 1
        best = 0
        while size <= self.n:
            if self.find(size) is not None:
                best = size
            size *= 2
        return best

    def aligned_starts(self, size: int) -> range:
        """Start indices of every size-aligned window (the only placeable
        positions); shared by find() and the scheduler's preemption scan."""
        return range(0, self.n - size + 1, size)

    def find(self, size: int, within: int | None = None) -> Range | None:
        """Smallest-index aligned free run of `size` slots, confined to
        the first `within` slots (None = the whole shell; the scheduler
        passes `n - reserve` to keep reserved slots out of reach)."""
        assert size >= 1 and (size & (size - 1)) == 0
        if size > self.n:
            return None
        limit = self.n if within is None else within
        for start in self.aligned_starts(size):
            if start + size > limit:
                break
            if all(i not in self.busy for i in range(start, start + size)):
                return Range(start, size)
        return None

    # -- mutation -----------------------------------------------------------

    def alloc(self, size: int) -> Range | None:
        r = self.find(size)
        if r is None:
            return None
        self.busy.update(r.slots)
        return r

    def alloc_at(self, r: Range) -> None:
        assert all(i not in self.busy for i in r.slots), "double alloc"
        assert r.start % r.size == 0, "unaligned"
        self.busy.update(r.slots)

    def free(self, r: Range) -> None:
        for i in r.slots:
            assert i in self.busy, f"double free of slot {i}"
            self.busy.discard(i)

    @property
    def utilization(self) -> float:
        return len(self.busy) / self.n
