"""Buddy allocator over shell slots.

Implements the paper's "combine adjacent PR regions" capability: allocations
are power-of-two runs of adjacent slots, aligned buddy-style so merges are
always possible when both buddies are free.  O(slots) per operation.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Range:
    start: int
    size: int

    @property
    def slots(self) -> tuple[int, ...]:
        return tuple(range(self.start, self.start + self.size))


class _BusySet(set):
    """Busy-slot set that mirrors every mutation into the allocator's
    bitmask, so window-freeness stays one shift+mask even for callers
    (tests, diagnostics) that poke `alloc.busy` directly.

    Every mutation routes through the owner's `_mark`/`_unmark`
    chokepoint (below) — the single place busy-set and bitmask move,
    which is what the schedlint mutation checker verifies."""

    def __init__(self, owner: "BuddyAllocator"):
        super().__init__()
        self._owner = owner

    def add(self, i):
        self._owner._mark(i)

    def discard(self, i):
        self._owner._unmark(i)

    def remove(self, i):
        if i not in self:
            raise KeyError(i)
        self._owner._unmark(i)

    def update(self, *others):
        for o in others:
            for i in o:
                self._owner._mark(i)

    def clear(self):
        # schedlint: ok(determinism) _unmark is commutative (discard +
        # mask clear): the final busy/mask state is iteration-order-free
        for i in tuple(self):
            self._owner._unmark(i)


class BuddyAllocator:
    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n = n_slots            # any count; allocations stay
        # busy slots as a bitmask, kept in lockstep with `busy`: window
        # freeness is one shift+mask instead of a per-slot set probe
        # (the scheduler's free-window scans are on the per-event path)
        self._mask = 0
        self.busy: set[int] = _BusySet(self)  # po2 sized & size-aligned
        self._lf_mask, self._lf_best = -1, 0  # largest_free memo

    # -- queries ------------------------------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i in range(self.n) if i not in self.busy]

    def window_free(self, start: int, size: int) -> bool:
        """Are slots [start, start+size) all free?  O(1) via the mask."""
        return (self._mask >> start) & ((1 << size) - 1) == 0

    def can_alloc(self, size: int, within: int | None = None) -> bool:
        return self.find(size, within) is not None

    def largest_free(self) -> int:
        if self._mask == self._lf_mask:
            return self._lf_best       # allocation state unchanged
        size = 1
        best = 0
        while size <= self.n:
            if self.find(size) is not None:
                best = size
            size *= 2
        self._lf_mask, self._lf_best = self._mask, best
        return best

    def aligned_starts(self, size: int) -> range:
        """Start indices of every size-aligned window (the only placeable
        positions); shared by find() and the scheduler's preemption scan."""
        return range(0, self.n - size + 1, size)

    def find(self, size: int, within: int | None = None) -> Range | None:
        """Smallest-index aligned free run of `size` slots, confined to
        the first `within` slots (None = the whole shell; the scheduler
        passes `n - reserve` to keep reserved slots out of reach)."""
        assert size >= 1 and (size & (size - 1)) == 0
        if size > self.n:
            return None
        limit = self.n if within is None else within
        window = (1 << size) - 1
        for start in self.aligned_starts(size):
            if start + size > limit:
                break
            if (self._mask >> start) & window == 0:
                return Range(start, size)
        return None

    # -- mutation -----------------------------------------------------------
    # `_mark`/`_unmark` are the single mutation chokepoint: every busy/
    # mask change — alloc, free, and direct `alloc.busy` pokes through
    # `_BusySet` — flows through them, so the busy set and the bitmask
    # cannot drift apart and the schedlint mutation checker has exactly
    # one pair of writers to verify.

    def _mark(self, i: int) -> None:
        set.add(self.busy, i)             # raw set op: no _BusySet loop
        self._mask |= 1 << i

    def _unmark(self, i: int) -> None:
        set.discard(self.busy, i)
        self._mask &= ~(1 << i)

    def alloc(self, size: int) -> Range | None:
        r = self.find(size)
        if r is None:
            return None
        for i in r.slots:
            self._mark(i)
        return r

    def alloc_at(self, r: Range) -> None:
        assert self.window_free(r.start, r.size), "double alloc"
        assert r.start % r.size == 0, "unaligned"
        for i in r.slots:
            self._mark(i)

    def free(self, r: Range) -> None:
        for i in r.slots:
            assert i in self.busy, f"double free of slot {i}"
            self._unmark(i)

    @property
    def utilization(self) -> float:
        return len(self.busy) / self.n
