"""FOS core: the paper's primary contribution, adapted to TPU pods.

- shell.py      shell/slot geometry (PR-region analogue)
- allocator.py  buddy allocation with adjacent-slot merging
- registry.py   JSON logical-hardware abstraction (shells + modules)
- module.py     decoupled AOT compilation, relocation, weight loading
- bus.py        layout adaptors (bus virtualisation analogue)
- scheduler.py  resource-elastic space-time policy (replicate/replace/reuse)
- arrivals.py   online arrival-rate estimation (predictive reservation)
- slo.py        per-tenant QoS contracts + predictive admission control
- checkpoint.py context save/restore for preempted chunks (priced, migratable)
- fabric.py     one scheduling contract over many shells (locality + stealing)
- simulator.py  discrete-event execution of the policy (tests + Fig 15)
- daemon.py     live multi-tenant execution service (a Fabric executor)
- zoo.py        module builders (mandelbrot/sobel/matmul/LM)
"""
from repro.core.allocator import BuddyAllocator, Range
from repro.core.arrivals import ArrivalEstimator
from repro.core.checkpoint import CheckpointManager, ChunkCheckpoint
from repro.core.daemon import Daemon, JobHandle
from repro.core.fabric import Fabric, FabricJob
from repro.core.network import FabricNetwork, Link, Transfer
from repro.core.registry import FabricDescriptor, ImplAlt, \
    ModuleDescriptor, Registry
from repro.core.scheduler import Assignment, CostModel, PolicyConfig, \
    Request, SchedulerState
from repro.core.shell import Shell, ShellSpec, SlotSpec, uniform_shell
from repro.core.simulator import SimJob, SimResult, simulate
from repro.core.slo import ADMIT, AdmissionController, \
    AdmissionRejected, AdmissionVerdict, DEGRADE, QoSContract, REJECT


def default_registry() -> Registry:
    """Registry preloaded with the benchmark accelerator zoo."""
    reg = Registry()
    from repro.core.shell import production_shells
    for spec in production_shells().values():
        reg.register_shell(spec)
    reg.register_module(ModuleDescriptor(
        name="mandelbrot", entrypoint="repro.core.zoo:build_mandelbrot",
        impls=(ImplAlt("x1", 1, 12.0), ImplAlt("x2", 2, 6.5),
               ImplAlt("x4", 4, 3.6)), kind="fn"))
    reg.register_module(ModuleDescriptor(
        name="sobel", entrypoint="repro.core.zoo:build_sobel",
        impls=(ImplAlt("x1", 1, 6.0), ImplAlt("x2", 2, 3.4)), kind="fn"))
    reg.register_module(ModuleDescriptor(
        name="matmul", entrypoint="repro.core.zoo:build_matmul",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.3)), kind="fn"))
    # lm-forward carries large activation state: its context save/restore
    # is priced above the policy default (ImplAlt.meta overrides)
    reg.register_module(ModuleDescriptor(
        name="lm-forward", entrypoint="repro.core.zoo:build_lm_forward",
        impls=(ImplAlt("x1", 1, 20.0,
                       meta={"ckpt_save_ms": 2.0, "ckpt_restore_ms": 2.0}),
               ImplAlt("x2", 2, 11.0,
                       meta={"ckpt_save_ms": 2.0, "ckpt_restore_ms": 2.0})),
        kind="fn"))
    # example multi-shell fabrics (Fabric.from_registry(reg, name))
    reg.register_fabric(FabricDescriptor("pod512", ("pod256_s4",
                                                    "pod256_s8")))
    reg.register_fabric(FabricDescriptor("hostpair", ("host8_s4",
                                                      "host4_s4")))
    # mixed board generations: a reference-clock shell next to a
    # half-clock one, with a modeled 2 ms cross-host payload transfer
    # per stolen chunk in either direction
    reg.register_fabric(FabricDescriptor(
        "hostpair_hetero", ("host8_s4", "host8_s4_lowclk"),
        transfer_ms={"host8_s4->host8_s4_lowclk": 2.0,
                     "host8_s4_lowclk->host8_s4": 2.0}))
    return reg
