"""Discrete-event simulator for the resource-elastic scheduler.

Drives the scheduling policy through the `Fabric` contract with a virtual
clock and the registry's cost model; used by property tests and by the
Fig.-15 benchmark (elastic vs fixed-module scheduling) as well as the
THEMIS-style preemption benchmark (benchmarks/preemption.py) and the
multi-shell stealing benchmark (benchmarks/multi_shell.py).

`simulate` accepts either a bare slot count (the seed single-shell form —
internally a degenerate one-shell fabric, with identical ids, event order
and metrics), a `{shell_name: n_slots}` mapping, or a pre-built `Fabric`
(pass the fabric when the caller wants to inspect its shared cost model or
steal counters afterwards; a fabric is single-use — one per run).  Multi-shell runs lay shells out side by side
on a global slot axis (each shell gets a contiguous offset range), so the
seed timeline format `(t_start, t_end, (slot, size), rid)` is unchanged
and per-shell views are recovered from `SimResult.per_shell`.

Preemption semantics: when the policy evicts an in-flight chunk, the
victim's occupancy is truncated at the eviction instant (it still counts
as slot occupancy, not as goodput), the chunk is requeued, and its
original completion event becomes a stale no-op.  Every submitted chunk
therefore still completes exactly once, even when idle shells steal
pending chunks across the fabric.  Without checkpointing the truncated
partial work is discarded (`SimResult.discarded_ms`); with
`PolicyConfig.ckpt` the compute beyond the run's own overheads is
preserved (`SimResult.reclaimed_ms`), the victims' context-save cost is
realized at the preemptor's start, and the resumed chunk runs only its
remaining fraction plus the restore cost (core/checkpoint.py).

Cost model: the *actual* simulated chunk time comes from the registry
(`ImplAlt.meta["true_chunk_ms"]` when present, else `est_chunk_ms`),
divided by the hosting shell's `speed` (heterogeneous fabrics), so a
mis-estimated module can be modeled; with `PolicyConfig.refine_cost_model`
the fabric's shared `CostModel` EWMA-converges its estimates (used by
placement decisions) onto the observed true times — reconfigured chunks
included, at elapsed minus the modeled penalty.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Iterable

from repro.core.fabric import Fabric
from repro.core.registry import Registry
from repro.core.scheduler import Assignment, PolicyConfig

# stale "done" events are lazily skipped on pop; once more than this
# many are pending AND they outnumber live events 2:1, the heap is
# compacted in one pass.  Module-level so tests can force compaction on
# small traces (the rebuild must be event-order-identical)
COMPACT_MIN_STALE = 64


def p95(latencies: list[float]) -> float:
    """p95 over a list of latencies (nearest-rank); 0.0 when empty."""
    if not latencies:
        return 0.0
    lat = sorted(latencies)
    return lat[max(0, math.ceil(0.95 * len(lat)) - 1)]


@dataclasses.dataclass(frozen=True)
class SimJob:
    t_arrive: float
    tenant: str
    module: str
    n_chunks: int
    priority: int = 0
    deadline_ms: float | None = None
    affinity: str | None = None         # pin dispatch to a fabric shell


@dataclasses.dataclass
class SimResult:
    makespan: float
    utilization: float                  # busy slot-time / (makespan * slots)
    reconfigurations: int
    request_latency: dict[int, float]   # rid -> finish - submit
    timeline: list                      # (t_start, t_end, slot_range, rid)
    preemptions: int = 0
    # truncated spans of evicted chunks: (t_start, t_evict, slot_range, rid)
    preempted_spans: list = dataclasses.field(default_factory=list)
    # slot-time of evicted runs (occupancy that produced no completed
    # chunk); splits into discarded_ms + reclaimed_ms below
    wasted_time: float = 0.0
    # rid -> {"tenant", "priority", "deadline_ms", "n_chunks"}
    request_meta: dict[int, dict] = dataclasses.field(default_factory=dict)
    n_slots: int = 1
    # shell name -> {"offset", "n_slots", "busy_ms", "utilization"}
    per_shell: dict[str, dict] = dataclasses.field(default_factory=dict)
    stolen_chunks: int = 0              # chunks moved by work stealing
    # evicted slot-time lost for good vs preserved by checkpoints
    # (invariant: discarded_ms + reclaimed_ms == wasted_time); with
    # checkpointing off every evicted span is discarded
    discarded_ms: float = 0.0
    reclaimed_ms: float = 0.0
    ckpt_saves: int = 0                 # context-save operations
    ckpt_restores: int = 0              # chunks resumed from a checkpoint
    ckpt_migrations: int = 0            # checkpoints moved across shells
    # shell name -> [(t_ms, effective reserve), ...] recorded on change
    # (adaptive reservation's sizing trace; static mode records its
    # constant once, a zero reservation records nothing)
    reserve_history: dict[str, list] = dataclasses.field(
        default_factory=dict)
    # per-tenant SLO attainment snapshot (core/slo.py): verdict counts,
    # deadline-hit fraction, bounded attainment history.  Empty — and
    # absent from golden serialisations — without registered contracts
    slo: dict = dataclasses.field(default_factory=dict)
    # observability snapshot (repro.obs.FlightRecorder.snapshot):
    # counters, per-tenant service-ms, sampled gauge history, scheduler
    # self-profile.  Empty — and absent from golden serialisations —
    # unless a recorder is attached to the fabric
    metrics: dict = dataclasses.field(default_factory=dict)

    @property
    def mean_latency(self) -> float:
        lat = list(self.request_latency.values())
        return sum(lat) / len(lat) if lat else 0.0

    def latencies(self, priority: int | None = None) -> list[float]:
        return sorted(
            l for rid, l in self.request_latency.items()
            if priority is None
            or self.request_meta[rid]["priority"] == priority)

    def p95_latency(self, priority: int | None = None) -> float:
        return p95(self.latencies(priority))

    def deadline_misses(self) -> int:
        n = 0
        for rid, lat in self.request_latency.items():
            dl = self.request_meta[rid]["deadline_ms"]
            if dl is not None and lat > dl + 1e-9:
                n += 1
        return n

    @property
    def deadline_miss_rate(self) -> float:
        with_dl = sum(1 for m in self.request_meta.values()
                      if m["deadline_ms"] is not None)
        return self.deadline_misses() / with_dl if with_dl else 0.0

    @property
    def useful_utilization(self) -> float:
        """Utilization counting only work that was not later discarded
        (checkpoint-reclaimed partial work still counts as useful)."""
        if self.makespan <= 0 or self.utilization <= 0:
            return 0.0
        return self.utilization - self.discarded_ms / (
            self.makespan * max(1, self.n_slots))


def _true_chunk_ms(registry: Registry, module: str, footprint: int,
                   speed: float) -> float:
    """Full-chunk true compute time on a shell (no penalties)."""
    impl = registry.module(module).impl_for(footprint)
    return impl.meta.get("true_chunk_ms", impl.est_chunk_ms) / speed


def chunk_time_ms(registry: Registry, a: Assignment,
                  policy: PolicyConfig, speed: float = 1.0) -> float:
    """True simulated service time of an assignment (the cost-model
    estimate may diverge; see `ImplAlt.meta["true_chunk_ms"]`).

    `speed` is the hosting shell's relative clock: compute scales by
    1/speed; the reconfiguration penalty does not (the configuration
    port is modeled as generation-independent).  A chunk resumed from a
    checkpoint (`a.frac < 1`) runs only its remaining fraction and pays
    its context-restore cost up front; `a.save_ms` realizes the evicted
    victims' context save at the preemptor's start."""
    t = _true_chunk_ms(registry, a.module, a.footprint, speed)
    if a.frac != 1.0:
        t *= a.frac
    if a.reconfigure:
        t += policy.reconfig_penalty_ms
    return t + a.restore_ms + a.save_ms


def _as_fabric(registry: Registry, spec, policy: PolicyConfig) -> Fabric:
    if isinstance(spec, Fabric):
        return spec
    if isinstance(spec, int):
        return Fabric({"shell0": spec}, registry, policy)
    return Fabric(dict(spec), registry, policy)


def simulate(registry: Registry, fabric_or_n_slots, jobs: Iterable[SimJob],
             policy: PolicyConfig | None = None) -> SimResult:
    """Replay `jobs` through the fabric's scheduling contract.

    `fabric_or_n_slots`: an int (one anonymous shell — the seed form), a
    `{name: n_slots}` mapping, or a `Fabric`.  When a Fabric is passed,
    its own `PolicyConfig` governs; passing a *different* policy too is
    rejected rather than silently ignored.
    """
    if isinstance(fabric_or_n_slots, Fabric):
        if policy is not None and policy is not fabric_or_n_slots.policy:
            raise ValueError(
                "simulate() got both a Fabric and a different "
                "PolicyConfig; the fabric's own policy governs — drop "
                "the policy argument or build the fabric with it")
        if fabric_or_n_slots.jobs:
            raise ValueError(
                "simulate() needs a fresh Fabric: this one already "
                "carries jobs from a previous run, which would pollute "
                "latency/steal metrics — build a new Fabric per run")
    policy = policy or PolicyConfig()
    fabric = _as_fabric(registry, fabric_or_n_slots, policy)
    policy = fabric.policy
    offsets, off = {}, 0
    for name, st in fabric.states.items():
        offsets[name] = off
        off += st.alloc.n
    total_slots = off

    events: list[tuple[float, int, str, object]] = []
    seq = 0
    for j in jobs:
        heapq.heappush(events, (j.t_arrive, seq, "arrive", j))
        seq += 1

    now = 0.0
    busy_time = 0.0
    wasted_time = 0.0
    discarded_ms = 0.0
    reclaimed_ms = 0.0
    reconfs = 0
    timeline = []
    preempted_spans = []
    starts: dict[int, float] = {}       # aid -> dispatch time
    meta: dict[int, dict] = {}
    busy_by_shell: dict[str, float] = {n: 0.0 for n in fabric.states}
    # transfer is paid once per stolen chunk — a preempted rerun of the
    # same chunk does not move the payload again
    paid_chunks: set[tuple[str, int, int]] = set()
    charged: dict[int, float] = {}      # aid -> transfer charged
    # aids evicted before their "done" event fired: the event stays in
    # the heap (lazy deletion) and is skipped on pop; when stale events
    # come to dominate the heap it is compacted in one pass — a high
    # preemption rate must not grow the heap without bound
    stale: set[int] = set()

    def dispatch(t0: float):
        nonlocal seq, busy_time, wasted_time, reconfs
        nonlocal discarded_ms, reclaimed_ms
        new = fabric.schedule(now=t0)
        if fabric.network.active:
            # a steal this pass reserved link occupancy: realize the
            # release as a timed "net" event, so queued thieves
            # re-evaluate (network.version re-dirties every shell) the
            # moment the route frees up — not one event later
            for xfer in fabric.network.drain_releases():
                heapq.heappush(events, (xfer.t_done, seq, "net", None))
                seq += 1
        for ck in fabric.drain_moved():
            # a steal retires the chunk's (shell, rid, chunk) identity:
            # release its transfer-charge record so a transfer-paid
            # chunk that is preempted and then re-stolen leaves no
            # residue (the re-steal is a fresh payload movement and is
            # priced under its new identity)
            paid_chunks.discard(ck)
        for shell, v in fabric.drain_preempted():
            stale.add(v.aid)
            tr = charged.pop(v.aid, 0.0)
            ts = starts.pop(v.aid)
            span = (t0 - ts) * v.rng.size
            busy_time += span
            busy_by_shell[shell] += span
            wasted_time += span
            reclaimed = 0.0
            if fabric.ckpt is not None and fabric.ckpt_capable[shell] \
                    and not fabric.states[shell].requests[v.rid].failed:
                # the run's compute beyond its overheads (restore, save,
                # reconfiguration, transfer) survives in the checkpoint,
                # capped at the work the run still had to do; overheads
                # themselves are gone for good
                over = v.restore_ms + v.save_ms + tr
                if v.reconfigure:
                    over += policy.reconfig_penalty_ms
                remaining = v.frac * _true_chunk_ms(
                    registry, v.module, v.footprint,
                    fabric.speeds[shell])
                reclaimed = min(max(0.0, (t0 - ts) - over),
                                remaining) * v.rng.size
            reclaimed_ms += reclaimed
            discarded_ms += span - reclaimed
            job, _ = fabric.resolve(shell, v)
            preempted_spans.append(
                (ts, t0, (offsets[shell] + v.rng.start, v.rng.size),
                 job.gid))
        if len(stale) > COMPACT_MIN_STALE \
                and 2 * len(stale) > len(events):
            # compact: drop the stale "done" entries and re-heapify.
            # (t, seq) is a unique total order, so rebuild pops the
            # surviving events in exactly the original order
            events[:] = [e for e in events
                         if e[2] != "done" or e[3][1].aid not in stale]
            heapq.heapify(events)
            stale.clear()
            if fabric.obs is not None:
                fabric.obs.prof["heap_compactions"] += 1
        for shell, a in new:
            # stolen chunks also pay the priced cross-shell payload
            # movement — the latency the steal gate reasons about is
            # realized in the simulated world, not just planned for
            tr = fabric.transfer_cost(shell, a.rid)
            if tr > 0.0:
                ck = (shell, a.rid, a.chunk)
                if ck in paid_chunks:
                    tr = 0.0            # rerun: payload already moved
                else:
                    paid_chunks.add(ck)
                    charged[a.aid] = tr
            dt = chunk_time_ms(registry, a, policy,
                               fabric.speeds[shell]) + tr
            if a.reconfigure:
                reconfs += 1
            starts[a.aid] = t0
            heapq.heappush(events, (t0 + dt, seq, "done", (shell, a)))
            seq += 1

    def admit(j: SimJob, t: float) -> None:
        job = fabric.submit(j.tenant, j.module, j.n_chunks,
                            now=t, priority=j.priority,
                            deadline_ms=j.deadline_ms,
                            affinity=j.affinity)
        m = {"tenant": j.tenant,
             "priority": j.priority,
             "deadline_ms": j.deadline_ms,
             "n_chunks": j.n_chunks,
             "t_submit": t}
        if job.verdict is not None:
            # admission-screened: record the structured verdict (keys
            # only exist on contract runs — the no-contract meta dict
            # is unchanged, byte for byte)
            m["verdict"] = job.verdict.action
            if job.degraded_from is not None:
                m["degraded_from"] = job.degraded_from
            if job.verdict.reason:
                m["verdict_reason"] = job.verdict.reason
        meta[job.gid] = m

    while events:
        now, _, kind, obj = heapq.heappop(events)
        if kind == "arrive":
            admit(obj, now)
            # coalesce a same-timestamp arrival storm into one
            # scheduling pass: every job offered at this instant is
            # admitted before placement runs.  Interleaving dispatch
            # between same-t submits (the pre-PR 6 behavior) let the
            # first job claim slots and bias steals before its
            # simultaneous peers even existed — an ordering bug, since
            # no event separates the arrivals.  Arrivals at equal t
            # always pop before "done" events (their seq numbers are
            # assigned first), so completions are unaffected.
            while events and events[0][0] == now \
                    and events[0][2] == "arrive":
                admit(heapq.heappop(events)[3], now)
        elif kind == "net":
            # link-release instant: free the expired occupancy, then
            # fall through to dispatch — backed-off steals re-run now
            for xfer in fabric.network.advance(now):
                if fabric.obs is not None:
                    fabric.obs.on_transfer_complete(xfer.src, xfer.dst,
                                                    now)
        else:
            shell, a = obj
            if a.aid in stale:
                stale.discard(a.aid)
                continue                 # evicted: the executor-side skip
            if not fabric.complete(shell, a, now=now):
                continue                 # stale event for a preempted chunk
            paid_chunks.discard((shell, a.rid, a.chunk))
            ts = starts.pop(a.aid)
            busy_time += (now - ts) * a.rng.size
            busy_by_shell[shell] += (now - ts) * a.rng.size
            job, _ = fabric.resolve(shell, a)
            timeline.append((ts, now,
                             (offsets[shell] + a.rng.start, a.rng.size),
                             job.gid))
            if policy.refine_cost_model:
                # reconfigured chunks are observed too, minus the
                # modeled penalty — a module that always reconfigures
                # must still refine its estimate; likewise the transfer
                # actually charged to this attempt, and the checkpoint
                # restore/save overheads, are not the module's own time.
                # A resumed chunk ran only its remaining fraction, so
                # its elapsed time is scaled back to a full chunk (a
                # zero-length resume observes nothing).
                extra = charged.get(a.aid, 0.0) + a.restore_ms \
                    + a.save_ms
                if a.reconfigure:
                    extra += policy.reconfig_penalty_ms
                elapsed = now - ts
                if extra > 0.0:
                    elapsed = max(1e-3, elapsed - extra)
                if a.frac >= 1e-9:
                    if a.frac != 1.0:
                        elapsed = elapsed / a.frac
                    fabric.cost.observe(a.module, a.footprint, elapsed,
                                        fabric.speeds[shell])
            charged.pop(a.aid, None)
        dispatch(now)

    assert all(j.complete or j.rejected
               for j in fabric.jobs.values()), \
        "simulator finished with incomplete requests"
    for st in fabric.states.values():
        assert not st.alloc.busy, "simulator finished with busy slots"
        assert not st.active, "simulator finished with in-flight chunks"
    assert fabric.ckpt is None or len(fabric.ckpt) == 0, \
        "simulator finished with unconsumed checkpoint records"
    # bookkeeping must drain exactly: every dispatched aid was either
    # completed or preempted (starts/charged), every stale "done" event
    # was skipped or compacted away, and every transfer charge was
    # released by completion or by the retirement of its chunk identity
    # at a re-steal (drain_moved) — the charge map is exact
    assert not starts and not charged and not stale \
        and not paid_chunks, \
        "simulator finished with leaked bookkeeping entries"
    assert fabric.network.inflight == 0, \
        "simulator finished with unreleased link occupancy"
    lat = {j.gid: j.t_finish - j.t_submit
           for j in fabric.jobs.values() if not j.rejected}
    util = busy_time / (now * total_slots) if now > 0 else 0.0
    n_pre = sum(st.n_preemptions for st in fabric.states.values())
    per_shell = {
        name: {"offset": offsets[name], "n_slots": st.alloc.n,
               "busy_ms": busy_by_shell[name],
               "utilization": (busy_by_shell[name] / (now * st.alloc.n)
                               if now > 0 else 0.0)}
        for name, st in fabric.states.items()}
    cstats = fabric.ckpt.stats if fabric.ckpt is not None else {}
    return SimResult(now, util, reconfs, lat, timeline,
                     preemptions=n_pre,
                     preempted_spans=preempted_spans,
                     wasted_time=wasted_time, request_meta=meta,
                     n_slots=total_slots, per_shell=per_shell,
                     stolen_chunks=fabric.stats["stolen_chunks"],
                     discarded_ms=discarded_ms,
                     reclaimed_ms=reclaimed_ms,
                     ckpt_saves=cstats.get("saves", 0),
                     ckpt_restores=cstats.get("restores", 0),
                     ckpt_migrations=cstats.get("migrations", 0),
                     reserve_history={
                         name: list(st.reserve_history)
                         for name, st in fabric.states.items()},
                     slo=(fabric.slo.attainment()
                          if fabric.slo is not None else {}),
                     metrics=(fabric.obs.snapshot()
                              if fabric.obs is not None else {}))
