"""Discrete-event simulator for the resource-elastic scheduler.

Drives the exact SchedulerState policy with a virtual clock and the
registry's cost model; used by property tests and by the Fig.-15 benchmark
(elastic vs fixed-module scheduling: utilization / makespan / latency) as
well as the THEMIS-style preemption benchmark (benchmarks/preemption.py).

Preemption semantics: when the policy evicts an in-flight chunk, the
victim's occupancy is truncated at the eviction instant (the partial work
is discarded — it still counts as slot occupancy, not as goodput), the
chunk is requeued, and its original completion event becomes a stale no-op.
Every submitted chunk therefore still completes exactly once.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Iterable

from repro.core.registry import Registry
from repro.core.scheduler import Assignment, PolicyConfig, SchedulerState


def p95(latencies: list[float]) -> float:
    """p95 over a list of latencies (nearest-rank); 0.0 when empty."""
    if not latencies:
        return 0.0
    lat = sorted(latencies)
    return lat[max(0, math.ceil(0.95 * len(lat)) - 1)]


@dataclasses.dataclass(frozen=True)
class SimJob:
    t_arrive: float
    tenant: str
    module: str
    n_chunks: int
    priority: int = 0
    deadline_ms: float | None = None


@dataclasses.dataclass
class SimResult:
    makespan: float
    utilization: float                  # busy slot-time / (makespan * slots)
    reconfigurations: int
    request_latency: dict[int, float]   # rid -> finish - submit
    timeline: list                      # (t_start, t_end, slot_range, rid)
    preemptions: int = 0
    # truncated spans of evicted chunks: (t_start, t_evict, slot_range, rid)
    preempted_spans: list = dataclasses.field(default_factory=list)
    wasted_time: float = 0.0            # slot-time of discarded partial work
    # rid -> {"tenant", "priority", "deadline_ms", "n_chunks"}
    request_meta: dict[int, dict] = dataclasses.field(default_factory=dict)
    n_slots: int = 1

    @property
    def mean_latency(self) -> float:
        lat = list(self.request_latency.values())
        return sum(lat) / len(lat) if lat else 0.0

    def latencies(self, priority: int | None = None) -> list[float]:
        return sorted(
            l for rid, l in self.request_latency.items()
            if priority is None
            or self.request_meta[rid]["priority"] == priority)

    def p95_latency(self, priority: int | None = None) -> float:
        return p95(self.latencies(priority))

    def deadline_misses(self) -> int:
        n = 0
        for rid, lat in self.request_latency.items():
            dl = self.request_meta[rid]["deadline_ms"]
            if dl is not None and lat > dl + 1e-9:
                n += 1
        return n

    @property
    def deadline_miss_rate(self) -> float:
        with_dl = sum(1 for m in self.request_meta.values()
                      if m["deadline_ms"] is not None)
        return self.deadline_misses() / with_dl if with_dl else 0.0

    @property
    def useful_utilization(self) -> float:
        """Utilization counting only work that was not later discarded."""
        if self.makespan <= 0 or self.utilization <= 0:
            return 0.0
        return self.utilization - self.wasted_time / (
            self.makespan * max(1, self.n_slots))


def chunk_time_ms(registry: Registry, a: Assignment,
                  policy: PolicyConfig) -> float:
    desc = registry.module(a.module)
    impl = desc.impl_for(a.footprint)
    t = impl.est_chunk_ms
    if a.reconfigure:
        t += policy.reconfig_penalty_ms
    return t


def simulate(registry: Registry, n_slots: int, jobs: Iterable[SimJob],
             policy: PolicyConfig | None = None) -> SimResult:
    policy = policy or PolicyConfig()
    state = SchedulerState(n_slots, registry, policy)
    events: list[tuple[float, int, str, object]] = []
    seq = 0
    for j in jobs:
        heapq.heappush(events, (j.t_arrive, seq, "arrive", j))
        seq += 1

    now = 0.0
    busy_time = 0.0
    wasted_time = 0.0
    reconfs = 0
    timeline = []
    preempted_spans = []
    starts: dict[int, float] = {}       # aid -> dispatch time
    meta: dict[int, dict] = {}

    def dispatch(t0: float):
        nonlocal seq, busy_time, wasted_time, reconfs
        new = state.schedule(now=t0)
        for v in state.drain_preempted():
            ts = starts.pop(v.aid)
            busy_time += (t0 - ts) * v.rng.size
            wasted_time += (t0 - ts) * v.rng.size
            preempted_spans.append((ts, t0, (v.rng.start, v.rng.size),
                                    v.rid))
        for a in new:
            dt = chunk_time_ms(registry, a, policy)
            if a.reconfigure:
                reconfs += 1
            starts[a.aid] = t0
            heapq.heappush(events, (t0 + dt, seq, "done", a))
            seq += 1

    while events:
        now, _, kind, obj = heapq.heappop(events)
        if kind == "arrive":
            req = state.submit(obj.tenant, obj.module, obj.n_chunks,
                               now=now, priority=obj.priority,
                               deadline_ms=obj.deadline_ms)
            meta[req.rid] = {"tenant": obj.tenant,
                             "priority": obj.priority,
                             "deadline_ms": obj.deadline_ms,
                             "n_chunks": obj.n_chunks}
        else:
            if not state.complete(obj, now=now):
                continue                 # stale event for a preempted chunk
            ts = starts.pop(obj.aid)
            busy_time += (now - ts) * obj.rng.size
            timeline.append((ts, now, (obj.rng.start, obj.rng.size),
                             obj.rid))
        dispatch(now)

    assert all(r.complete for r in state.requests.values()), \
        "simulator finished with incomplete requests"
    assert not state.alloc.busy, "simulator finished with busy slots"
    assert not state.active, "simulator finished with in-flight chunks"
    lat = {rid: r.t_finish - r.t_submit
           for rid, r in state.requests.items()}
    util = busy_time / (now * state.alloc.n) if now > 0 else 0.0
    return SimResult(now, util, reconfs, lat, timeline,
                     preemptions=state.n_preemptions,
                     preempted_spans=preempted_spans,
                     wasted_time=wasted_time, request_meta=meta,
                     n_slots=state.alloc.n)
