"""Discrete-event simulator for the resource-elastic scheduler.

Drives the exact SchedulerState policy with a virtual clock and the
registry's cost model; used by property tests and by the Fig.-15 benchmark
(elastic vs fixed-module scheduling: utilization / makespan / latency).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable

from repro.core.registry import Registry
from repro.core.scheduler import Assignment, PolicyConfig, SchedulerState


@dataclasses.dataclass(frozen=True)
class SimJob:
    t_arrive: float
    tenant: str
    module: str
    n_chunks: int


@dataclasses.dataclass
class SimResult:
    makespan: float
    utilization: float                  # busy slot-time / (makespan * slots)
    reconfigurations: int
    request_latency: dict[int, float]   # rid -> finish - submit
    timeline: list                      # (t_start, t_end, slot_range, rid)

    @property
    def mean_latency(self) -> float:
        lat = list(self.request_latency.values())
        return sum(lat) / len(lat) if lat else 0.0


def chunk_time_ms(registry: Registry, a: Assignment,
                  policy: PolicyConfig) -> float:
    desc = registry.module(a.module)
    impl = desc.impl_for(a.footprint)
    t = impl.est_chunk_ms
    if a.reconfigure:
        t += policy.reconfig_penalty_ms
    return t


def simulate(registry: Registry, n_slots: int, jobs: Iterable[SimJob],
             policy: PolicyConfig | None = None) -> SimResult:
    policy = policy or PolicyConfig()
    state = SchedulerState(n_slots, registry, policy)
    events: list[tuple[float, int, str, object]] = []
    seq = 0
    for j in jobs:
        heapq.heappush(events, (j.t_arrive, seq, "arrive", j))
        seq += 1

    now = 0.0
    busy_time = 0.0
    reconfs = 0
    timeline = []

    def dispatch(t0: float):
        nonlocal seq, busy_time, reconfs
        for a in state.schedule():
            dt = chunk_time_ms(registry, a, policy)
            if a.reconfigure:
                reconfs += 1
            busy_time += dt * a.rng.size
            timeline.append((t0, t0 + dt, (a.rng.start, a.rng.size), a.rid))
            heapq.heappush(events, (t0 + dt, seq, "done", a))
            seq += 1

    while events:
        now, _, kind, obj = heapq.heappop(events)
        if kind == "arrive":
            state.submit(obj.tenant, obj.module, obj.n_chunks, now=now)
        else:
            state.complete(obj, now=now)
        dispatch(now)

    assert all(r.complete for r in state.requests.values()), \
        "simulator finished with incomplete requests"
    lat = {rid: r.t_finish - r.t_submit
           for rid, r in state.requests.items()}
    util = busy_time / (now * state.alloc.n) if now > 0 else 0.0
    return SimResult(now, util, reconfs, lat, timeline)
