"""SLO-aware admission control: per-tenant QoS contracts.

Under overload the fabric's default contract is "admit everything": the
global admission queue grows without bound and every tenant's deadline
collapses at once — the failure mode THEMIS (Karabulut et al., 2024)
frames for multi-tenant FPGA arbitration, and the one the per-tenant
isolation contract of Mandebi Mbongue et al. (2020) exists to prevent.
This module adds the missing subsystem: tenants may attach a
`QoSContract` (a declared arrival rate, a deadline at a percentile, and
optionally a *degraded mode* naming a cheaper registered module — the
analogue of a smaller / lower-fidelity bitstream tier of the same
accelerator), and every `Fabric.submit` is then screened by an
`AdmissionController` that predicts whether admitting the job keeps
**every registered contract** feasible.  The verdict is structured:

  - ``ADMIT``   — every contract stays feasible with the job included;
  - ``DEGRADE`` — the job as offered would break a contract, but the
    submitting tenant's own degraded mode fits: the job is transparently
    swapped to the cheaper module (`FabricJob.degraded_from` records the
    original);
  - ``REJECT``  — no feasible form exists; the verdict carries the
    predicted violation (which contract, predicted vs target) as the
    reason, so shedding is *predictable* instead of every deadline
    failing at once.

Feasibility model (Little's law over the fabric's committed state; all
quantities are reference-speed milliseconds, `CostModel` units):

  capacity   = sum over shells of n_slots * speed      [slot-ms per ms]
  backlog    = sum over shells of _backlog_ms * speed  [slot-ms]
               (the fabric's memoized per-shell estimate: queued chunks
               plus in-flight work, exactly what dispatch ECT uses)
  rho        = contract load + background load, where each contract
               contributes declared_rate x EWMA job slot-ms (its
               *protected* share, staleness-decayed once the tenant
               stops offering — `ArrivalEstimator.STALE_FACTOR`
               semantics) and the background is an `ArrivalEstimator`
               over non-contract admitted arrivals (one observation per
               admitted job, service = the whole job's slot-ms); a
               background class only counts once it has `MIN_CLASS_OBS`
               arrivals — before that its work is priced through the
               backlog term alone
  wait       = (backlog + candidate work) / capacity / (1 - rho)
               — the queue drain time, inflated by the predicted
               steady-state congestion; rho >= admission_rho_max is
               outright infeasible (the denominator would predict an
               unbounded queue)
  pred(c)    = (wait + reconfig_penalty + service(c)) * tail(percentile)

with `tail(p) = max(1, -ln(1 - p))` — the exponential-tail percentile
inflation (p95 ~ 3x the mean, p99 ~ 4.6x).  A contract is feasible iff
`pred(c) <= c.deadline_ms`.  The check runs against every registered
contract, the submitting tenant's own included, with the candidate
job's work folded into the backlog term — so one tenant's burst is
rejected (or degraded) the moment it would push *anyone's* predicted
percentile past their target, not after the queue has already sunk
every deadline.

Attainment accounting: the controller counts submitted / admitted /
degraded / rejected per tenant, and for contract tenants scores every
completion against its deadline (the job's own `deadline_ms`, defaulted
to the contract's), keeping a bounded attainment history
`[(t_ms, hit_fraction), ...]`.  `SimResult.slo` and `Daemon.slo_stats`
surface the same snapshot.

Everything here is opt-in: a fabric with no registered contract never
constructs a controller, and the no-contract path is byte-identical to
the pre-SLO scheduling contract (pinned by the golden corpus and a
property test in tests/test_slo.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING

from repro.core.arrivals import ArrivalEstimator, STALE_FACTOR

if TYPE_CHECKING:                                  # pragma: no cover
    from repro.core.fabric import Fabric

ADMIT = "ADMIT"
DEGRADE = "DEGRADE"
REJECT = "REJECT"

# "every priority class" sentinel for ArrivalEstimator.demand_slots:
# with no class below it, blocking_ms is 0 and the demand collapses to
# sum(rate * service * footprint) — exactly the background load term
_ALL_CLASSES = -(1 << 30)

# bounded per-tenant attainment history (long-daemon hygiene)
HISTORY_MAX = 512

# a background class needs this many arrivals before its estimated rate
# counts toward the utilisation check: live submits land back to back
# (microsecond gaps), and an EWMA seeded by one such pair would read as
# thousands of jobs per second and veto every tenant until staleness
# decays it.  Work those first arrivals actually offered is still fully
# counted — it sits in the backlog term.
MIN_CLASS_OBS = 4


class AdmissionRejected(RuntimeError):
    """A submit was rejected by admission control; carries the verdict."""

    def __init__(self, verdict: "AdmissionVerdict"):
        super().__init__(verdict.reason)
        self.verdict = verdict


@dataclasses.dataclass(frozen=True)
class QoSContract:
    """One tenant's service-level contract.

    `rate_per_s` is the *declared* arrival rate the fabric protects
    capacity for (jobs per second); `deadline_ms` is the per-job latency
    target at `percentile`.  `degraded` optionally names a cheaper
    registered module — the degraded implementation tier of the
    tenant's accelerator — that ``DEGRADE`` verdicts transparently swap
    the job to; it is validated against the registry when the contract
    is registered (unknown names raise the registry's rich KeyError).
    """
    tenant: str
    rate_per_s: float
    deadline_ms: float
    percentile: float = 0.95
    degraded: str | None = None

    def __post_init__(self):
        if self.rate_per_s <= 0.0:
            raise ValueError(f"contract rate_per_s must be positive, "
                             f"got {self.rate_per_s}")
        if self.deadline_ms <= 0.0:
            raise ValueError(f"contract deadline_ms must be positive, "
                             f"got {self.deadline_ms}")
        if not 0.0 < self.percentile < 1.0:
            raise ValueError(f"contract percentile must be in (0, 1), "
                             f"got {self.percentile}")

    @property
    def ia_ms(self) -> float:
        """Declared inter-arrival in scheduler milliseconds."""
        return 1000.0 / self.rate_per_s

    @property
    def tail_factor(self) -> float:
        """Exponential-tail inflation from mean to `percentile`."""
        return max(1.0, -math.log(1.0 - self.percentile))


@dataclasses.dataclass(frozen=True)
class AdmissionVerdict:
    """Structured outcome of one admission decision."""
    action: str                         # ADMIT | DEGRADE | REJECT
    tenant: str                         # submitting tenant
    reason: str = ""                    # predicted violation (non-ADMIT)
    predicted_ms: float | None = None   # percentile latency that decided
    violated: str | None = None         # contract tenant predicted broken
    degraded_to: str | None = None      # module a DEGRADE swapped to


@dataclasses.dataclass
class _TenantLoad:
    """Per-contract-tenant load state: the declared-rate share is held
    while the tenant keeps offering work and staleness-decays once it
    stops (same STALE_FACTOR contract as the arrival estimator)."""
    last_t: float                       # most recent offered arrival
    slot_ms: float = 0.0                # EWMA slot-ms per admitted job
    serial_ms: float = 0.0              # EWMA serial service ms per job


class AdmissionController:
    """Contract screening at `Fabric.submit` (see module docstring).

    Owns its own estimators — the fabric's adaptive-reservation
    `ArrivalEstimator` (when present) keeps observing every arrival
    exactly as before, so reservation sizing is untouched by admission
    control; mixing the two would double-count contract tenants.
    """

    def __init__(self, fabric: "Fabric"):
        self.fabric = fabric
        self.registry = fabric.registry
        self.policy = fabric.policy
        self.contracts: dict[str, QoSContract] = {}
        self._load: dict[str, _TenantLoad] = {}
        # non-contract admitted arrivals, by priority class; service_ms
        # carries the whole job's slot-ms (footprint folded in), so
        # demand_slots(_ALL_CLASSES) returns the background load directly
        self.bg = ArrivalEstimator(self.policy.admission_alpha)
        self.counts: dict[str, dict[str, int]] = {}
        self.history: dict[str, list[tuple[float, float]]] = {}

    # -- registration ---------------------------------------------------------

    def register(self, contract: QoSContract, now: float = 0.0) -> None:
        """Register (or replace) a tenant's contract.  The degraded
        module name is validated against the registry — unknown names
        raise the registry's rich KeyError, like `Registry.shell()`."""
        if contract.degraded is not None:
            self.registry.module(contract.degraded)
        prev = self._load.get(contract.tenant)
        self.contracts[contract.tenant] = contract
        if prev is None:
            # the share anchors at registration: a contract that never
            # submits decays off within a few declared inter-arrivals
            self._load[contract.tenant] = _TenantLoad(last_t=now)

    # -- load model -----------------------------------------------------------

    def _capacity(self) -> float:
        """Fabric capacity in reference-speed slot-ms per ms, at the
        shells' *decision* speeds (what placement ECT plans with)."""
        return sum(st.alloc.n * st.speed
                   for st in self.fabric.states.values())

    def _backlog_ref(self) -> float:
        """Committed work across the fabric in reference slot-ms —
        the memoized per-shell `_backlog_ms` estimates, de-normalised
        back to reference speed."""
        return sum(self.fabric._backlog_ms(name) * st.speed
                   for name, st in self.fabric.states.items())

    def _contract_rate(self, tenant: str, now: float) -> float:
        """Declared arrival rate [1/ms], staleness-decayed once the
        tenant stops offering: 1 / max(declared ia, gap/STALE_FACTOR)."""
        c = self.contracts[tenant]
        gap = max(0.0, now - self._load[tenant].last_t)
        return 1.0 / max(c.ia_ms, gap / STALE_FACTOR, 1e-6)

    def _rho(self, now: float) -> float:
        """Predicted steady-state utilisation of the offered streams:
        every contract's protected share plus the observed background."""
        cap = self._capacity()
        if cap <= 0.0:
            return float("inf")
        load = self.bg.demand_slots(_ALL_CLASSES, now,
                                    min_obs=MIN_CLASS_OBS)
        for tenant in self.contracts:
            load += self._contract_rate(tenant, now) * \
                self._load[tenant].slot_ms
        return load / cap

    def _job_cost(self, module: str, n_chunks: int) -> tuple[float, float]:
        """(slot-ms of work, serial service ms) of one job of `module`
        at its smallest footprint, reference speed."""
        fp = min(self.registry.module(module).footprints)
        est = self.fabric.cost.est_chunk_ms(module, fp)
        return n_chunks * est * fp, n_chunks * est

    # -- the decision ---------------------------------------------------------

    def _first_violation(self, tenant: str, cand_slot_ms: float,
                         cand_serial_ms: float, now: float) \
            -> tuple[QoSContract, float] | None:
        """The first registered contract whose predicted percentile
        latency exceeds its deadline with the candidate job folded in
        (registration order — deterministic), or None if all hold."""
        rho = self._rho(now)
        if rho >= self.policy.admission_rho_max:
            # the queue would grow without bound: every finite deadline
            # is infeasible, report against the first contract
            c = next(iter(self.contracts.values()))
            return c, float("inf")
        cap = self._capacity()
        wait = (self._backlog_ref() + cand_slot_ms) / cap / (1.0 - rho)
        for c in self.contracts.values():
            if c.tenant != tenant \
                    and self._load[c.tenant].slot_ms == 0.0:
                # no admitted stream yet: there is nothing to protect,
                # and an idle contract (possibly one no fabric could
                # ever meet) must not veto other tenants' admission —
                # its share anchors on its own first admitted job,
                # while its own submits are always screened
                continue
            svc = cand_serial_ms if c.tenant == tenant \
                else self._load[c.tenant].serial_ms
            pred = (wait + self.policy.reconfig_penalty_ms + svc) \
                * c.tail_factor
            if pred > c.deadline_ms:
                return c, pred
        return None

    def decide(self, tenant: str, module: str, n_chunks: int,
               now: float) -> AdmissionVerdict:
        """Screen one offered job.  Does not mutate load state — the
        fabric reports the outcome back through `note_admitted` /
        `note_rejected` so only work that actually enters the system
        shapes future predictions."""
        slot_ms, serial_ms = self._job_cost(module, n_chunks)
        hit = self._first_violation(tenant, slot_ms, serial_ms, now)
        if hit is None:
            return AdmissionVerdict(ADMIT, tenant)
        mine = self.contracts.get(tenant)
        if mine is not None and mine.degraded is not None \
                and mine.degraded != module:
            d_slot, d_serial = self._job_cost(mine.degraded, n_chunks)
            if self._first_violation(tenant, d_slot, d_serial,
                                     now) is None:
                c, pred = hit
                return AdmissionVerdict(
                    DEGRADE, tenant, degraded_to=mine.degraded,
                    predicted_ms=pred, violated=c.tenant,
                    reason=(f"as offered, contract {c.tenant!r} "
                            f"predicts p{c.percentile * 100:g} "
                            f"{pred:.1f} ms > {c.deadline_ms:g} ms; "
                            f"degraded to {mine.degraded!r}"))
        c, pred = hit
        return AdmissionVerdict(
            REJECT, tenant, predicted_ms=pred, violated=c.tenant,
            reason=(f"admitting would break contract {c.tenant!r}: "
                    f"predicted p{c.percentile * 100:g} latency "
                    f"{pred:.1f} ms > deadline {c.deadline_ms:g} ms "
                    f"(offered utilisation "
                    f"{min(self._rho(now), 99.0):.2f})"))

    # -- outcome accounting ---------------------------------------------------

    def _counts(self, tenant: str) -> dict[str, int]:
        c = self.counts.get(tenant)
        if c is None:
            c = self.counts[tenant] = {
                "submitted": 0, "admitted": 0, "degraded": 0,
                "rejected": 0, "completed": 0, "hits": 0, "misses": 0}
        return c

    def note_admitted(self, tenant: str, module: str, n_chunks: int,
                      priority: int, now: float,
                      degraded: bool = False) -> None:
        """An offered job entered the system (possibly degraded)."""
        cnt = self._counts(tenant)
        cnt["submitted"] += 1
        cnt["degraded" if degraded else "admitted"] += 1
        slot_ms, serial_ms = self._job_cost(module, n_chunks)
        load = self._load.get(tenant)
        if load is not None:              # contract tenant
            a = self.policy.admission_alpha
            load.last_t = max(load.last_t, now)
            load.slot_ms = slot_ms if load.slot_ms == 0.0 \
                else a * slot_ms + (1.0 - a) * load.slot_ms
            load.serial_ms = serial_ms if load.serial_ms == 0.0 \
                else a * serial_ms + (1.0 - a) * load.serial_ms
        else:
            self.bg.observe(priority, now, service_ms=slot_ms)

    def note_rejected(self, tenant: str, now: float) -> None:
        """An offered job was shed.  A contract tenant's offered stream
        keeps its protected share alive (that is what the contract
        buys); rejected background work shapes nothing."""
        cnt = self._counts(tenant)
        cnt["submitted"] += 1
        cnt["rejected"] += 1
        load = self._load.get(tenant)
        if load is not None:
            load.last_t = max(load.last_t, now)

    def record_completion(self, tenant: str, latency_ms: float,
                          deadline_ms: float | None, now: float) -> None:
        """Score a finished job of a contract tenant against its
        deadline and extend the attainment history."""
        if tenant not in self.contracts:
            return
        cnt = self._counts(tenant)
        cnt["completed"] += 1
        dl = self.contracts[tenant].deadline_ms \
            if deadline_ms is None else deadline_ms
        if latency_ms <= dl + 1e-9:
            cnt["hits"] += 1
        else:
            cnt["misses"] += 1
        hist = self.history.setdefault(tenant, [])
        hist.append((now, cnt["hits"] / cnt["completed"]))
        if len(hist) > HISTORY_MAX:
            del hist[:len(hist) - HISTORY_MAX]

    # -- reporting ------------------------------------------------------------

    def attainment(self) -> dict[str, dict]:
        """Per-tenant SLO snapshot: verdict counts, deadline-hit
        fraction among completed jobs (contract tenants), and the
        bounded attainment history."""
        out: dict[str, dict] = {}
        for tenant in sorted(set(self.counts) | set(self.contracts)):
            cnt = dict(self._counts(tenant))
            entry: dict = dict(cnt)
            entry["contract"] = tenant in self.contracts
            entry["attainment"] = (cnt["hits"] / cnt["completed"]
                                   if cnt["completed"] else None)
            entry["history"] = [list(h)
                                for h in self.history.get(tenant, [])]
            out[tenant] = entry
        return out

    def totals(self) -> dict[str, int]:
        """Verdict/outcome counters summed across every tenant — the
        flat gauge surface the flight recorder (repro.obs) samples.
        Conservation holds by construction (and is property-tested):
        ``admitted + degraded + rejected == submitted``."""
        out = {"submitted": 0, "admitted": 0, "degraded": 0,
               "rejected": 0, "completed": 0, "hits": 0, "misses": 0}
        for cnt in self.counts.values():
            for k in out:
                out[k] += cnt[k]
        return out
