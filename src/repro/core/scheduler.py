"""Resource-elastic space-time scheduler (paper section 4.4).

Pure policy core, shared by the discrete-event simulator (tests, Fig-15
benchmark) and the live daemon executor:

  - weighted priority scheduling between tenants at acceleration-request
    granularity (equal priorities degrade to least-recently-served
    round robin, the paper's Fig-14 policy);
  - each request carries independent data-parallel *chunks* (work-groups);
  - module REPLICATION: chunks of one request run on many slots;
  - module REPLACEMENT: when adjacent slots are free, a bigger
    implementation alternative is placed on the merged range;
  - REUSE: a range still hosting the right module skips reconfiguration;
  - PREEMPTION (THEMIS-style): a high-priority arrival may evict the
    lowest-priority resident chunk mid-flight; the victim chunk is
    requeued and the preemptor pays the modeled reconfiguration penalty.
  - CHECKPOINTING (PolicyConfig.ckpt, core/checkpoint.py): an evicted
    chunk's progress is snapshotted (priced context save, realized by
    the preemptor) instead of discarded, and the chunk later resumes
    with only its remaining fraction plus the priced restore cost.
  - RESERVATION (PolicyConfig.reserve_slots): the last N slots are held
    back from non-interactive requests so a predicted interactive burst
    finds capacity without evicting anyone.  With
    PolicyConfig.reserve_mode == "adaptive" the count is no longer a
    static knob: an ArrivalEstimator (core/arrivals.py) tracks the
    observed interactive arrival rate and every scheduling pass sizes
    the effective reservation from predicted demand over the next
    reconfiguration+chunk horizon, clamped to [0, reserve_slots_max].
    A request whose *aged* effective priority reaches reserve_priority
    may use reserved slots once its tenant has gone a full starvation
    bound with no service at all (the reservation defers batch work,
    it must not starve it — but a backlogged-and-served tenant never
    pierces the burst headroom), and a reservation a module cannot fit
    under is shrunk to the largest feasible value, never silently
    dropped.

Priority model: each request carries an integer `priority` (higher wins)
and an optional relative `deadline_ms`.  The effective priority ages by
one level per `starvation_bound_ms` of queueing delay, so low-priority
tenants can be delayed at most `(gap + 1) * starvation_bound_ms` behind a
saturating higher-priority stream.  Ties break earliest-deadline-first,
then least-recently-served round robin.  The scheduler clock is in
milliseconds (the simulator's virtual clock; the daemon feeds
`time.perf_counter() * 1e3`).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Optional

from repro.analysis import sanitizer
from repro.core.allocator import BuddyAllocator, Range
from repro.core.arrivals import ArrivalEstimator
from repro.core.checkpoint import CheckpointManager
from repro.core.registry import ModuleDescriptor


@dataclasses.dataclass
class Request:
    rid: int
    tenant: str
    module: str
    n_chunks: int
    payloads: list | None = None          # live mode: per-chunk args
    priority: int = 0                     # higher wins
    deadline_ms: float | None = None      # relative to t_submit
    done: int = 0
    t_submit: float = 0.0
    t_finish: float | None = None
    t_last_served: float | None = None    # last chunk issue (aging anchor)
    preemptions: int = 0                  # chunks evicted mid-flight
    failed: bool = False                  # aborted after a chunk error

    def __post_init__(self):
        # chunk ids not yet issued; preempted chunks return to the front
        self._chunks: deque[int] = deque(range(self.n_chunks))

    def next_chunk(self) -> int:
        return self._chunks.popleft()

    def requeue_chunk(self, chunk: int) -> None:
        self._chunks.appendleft(chunk)
        self.preemptions += 1

    @property
    def pending(self) -> int:
        return 0 if self.failed else len(self._chunks)

    @property
    def issued(self) -> int:
        return self.n_chunks - len(self._chunks)

    @property
    def outstanding(self) -> int:
        return self.issued - self.done

    @property
    def complete(self) -> bool:
        return self.done >= self.n_chunks

    @property
    def finished(self) -> bool:
        """Complete, or aborted with no chunks still in flight."""
        return self.complete or (self.failed and self.outstanding == 0)

    @property
    def deadline_at(self) -> float:
        if self.deadline_ms is None:
            return float("inf")
        return self.t_submit + self.deadline_ms


@dataclasses.dataclass(frozen=True)
class Assignment:
    rid: int
    chunk: int
    module: str
    footprint: int
    rng: Range
    reconfigure: bool                     # False -> reused resident module
    aid: int = -1                         # unique per issued assignment
    # effective priority at placement time: a chunk defends itself at the
    # level it won the slot with (aging resets on service, so a starved
    # request's hard-earned promotion must not evaporate mid-chunk)
    eff: int = 0
    # -- checkpoint/restore (core/checkpoint.py) -------------------------
    t_start: float = 0.0                  # placement instant (progress base)
    frac: float = 1.0                     # fraction of the chunk still to run
    restore_ms: float = 0.0               # context-restore cost, paid up front
    # context-save cost of the victims this assignment evicted, net of the
    # overlap with its own reconfiguration (save readback and configuration
    # use distinct ports, so only the excess delays the preemptor)
    save_ms: float = 0.0


@dataclasses.dataclass
class PolicyConfig:
    # prefer the largest implementation alternative when the system is
    # otherwise idle (paper: "attempts to use the biggest module")
    upsize_when_idle: bool = True
    # estimated reconfiguration cost relative to a chunk (cost model)
    reconfig_penalty_ms: float = 5.0
    elastic: bool = True                  # False -> fixed 1-slot scheduling
    preemptive: bool = False              # allow chunk-granularity eviction
    # aging: each full bound of queueing delay buys one priority level
    starvation_bound_ms: float = 100.0
    # evict only when the preemptor outranks the victim by at least this
    # many effective-priority levels (prevents same-class thrash)
    preempt_margin: int = 1
    # -- fabric-level policy (core/fabric.py) ----------------------------
    # dispatch to the shell already hosting the module resident (dodges
    # the reconfiguration penalty), falling back to least-loaded
    locality: bool = True
    # an idle shell pulls pending chunks queued behind a busy shell's
    # backlog (only meaningful for multi-shell fabrics, elastic mode)
    steal: bool = True
    # EWMA-refine est_chunk_ms per (module, footprint) from observed
    # chunk service times (daemon: wall clock; simulator: true times);
    # reconfigured chunks are observed too, at elapsed - reconfig penalty
    refine_cost_model: bool = False
    refine_alpha: float = 0.3             # weight of the newest observation
    # -- fabric heterogeneity (core/fabric.py) ---------------------------
    # modeled cross-shell payload-movement cost per stolen chunk; a
    # Fabric / FabricDescriptor may override it per (victim, thief)
    # pair, or replace the scalar model wholesale with a link-level
    # FabricNetwork topology (core/network.py)
    transfer_ms: float = 0.0
    # on a link topology, steal/migration/dispatch gates consult
    # queue-aware transfer estimates (current link occupancy, bounded
    # buffers -> inf when full).  False degrades every estimate to the
    # zero-load figure — the scalar model's belief replayed on real
    # links, the baseline benchmarks/network_contention.py gates
    # against.  Inert on the uniform (scalar) shim
    congestion_aware: bool = True
    # inform placement and steal economics with true per-shell speeds;
    # False treats every shell as speed 1.0 for *decisions* (the
    # benchmark's speed-blind baseline — true service times still apply)
    speed_aware: bool = True
    # -- checkpoint/restore (core/checkpoint.py) -------------------------
    # snapshot an evicted chunk's progress instead of discarding it; the
    # chunk later resumes with only its remaining fraction plus the
    # modeled restore cost.  Off by default: the ckpt=False path is
    # byte-identical to the pre-checkpoint contract (property-tested)
    ckpt: bool = False
    # modeled context save/restore costs; per-implementation overrides
    # via ImplAlt.meta["ckpt_save_ms"/"ckpt_restore_ms"].  Both scale
    # with shell speed like chunk times (context moves through the
    # shell's own fabric, unlike the configuration port)
    ckpt_save_ms: float = 1.0
    ckpt_restore_ms: float = 1.0
    # -- steal-aware admission reservation -------------------------------
    # hold back the last N aligned slots of every shell from requests of
    # base priority < reserve_priority, so a predicted interactive burst
    # finds capacity without evicting anyone — the cheap alternative to
    # checkpointed preemption.  A reservation that would leave a module
    # unplaceable forever is shrunk for that request (no wedged jobs)
    reserve_slots: int = 0
    reserve_priority: int = 1
    # -- predictive reservation (core/arrivals.py) -----------------------
    # "static" (default) sizes the reservation from reserve_slots;
    # "adaptive" sizes it every scheduling pass from the observed
    # interactive arrival rate (a Little's-law demand estimate over the
    # next reconfiguration+chunk horizon), clamped to
    # [0, reserve_slots_max] — reserve_slots is ignored in that mode
    reserve_mode: str = "static"
    reserve_slots_max: int = 1
    # EWMA weight of the newest inter-arrival/service observation
    arrival_alpha: float = 0.3
    # -- SLO-aware admission control (core/slo.py) -----------------------
    # EWMA weight of the admission controller's own load estimates
    # (per-contract job slot-ms and the background arrival stream); the
    # controller only exists once a QoSContract is registered, so these
    # knobs are inert on the no-contract path
    admission_alpha: float = 0.3
    # offered utilisation at or above which every finite deadline is
    # predicted infeasible (the Little's-law queue would grow without
    # bound); kept below 1.0 so the model saturates before the fabric
    admission_rho_max: float = 0.95


class CostModel:
    """Per-(module, footprint) chunk-time estimates, refined online.

    Starts from the registry's static `est_chunk_ms` and, when
    `PolicyConfig.refine_cost_model` is on, EWMA-updates from observed
    chunk service times (`observe`).  One instance is shared by every
    SchedulerState in a Fabric so an observation on any shell improves
    placement everywhere.

    Estimates are stored speed-normalised (a speed-1.0 shell's time):
    `est_chunk_ms(..., speed=s)` divides by the querying shell's speed,
    and `observe(..., speed=s)` multiplies the wall time back, so an
    observation on a slow shell still refines placement on a fast one.
    Speed 1.0 is the exact identity — the homogeneous path returns the
    same floats as before.
    """

    def __init__(self, registry, alpha: float = 0.3):
        self.registry = registry
        self.alpha = alpha
        self._est: dict[tuple[str, int], float] = {}
        # bumped on every observation: backlog/ECT caches keyed on it
        # are invalidated fabric-wide the moment an estimate moves
        self.version = 0

    def est_chunk_ms(self, module: str, footprint: int,
                     speed: float = 1.0) -> float:
        v = self._est.get((module, footprint))
        if v is None:
            v = self.registry.module(module).impl_for(
                footprint).est_chunk_ms
        return v / speed

    def observe(self, module: str, footprint: int, ms: float,
                speed: float = 1.0) -> None:
        key = (module, footprint)
        ms = ms * speed
        prev = self._est.get(key)
        self._est[key] = ms if prev is None else \
            self.alpha * ms + (1.0 - self.alpha) * prev
        self.version += 1


# -- schedlint contract (repro.analysis) ------------------------------------
# One source of truth shared by the code and the static checker
# (`python -m repro.analysis`): the incremental fabric core elides
# scheduling passes for shells whose `_version` has not moved, so every
# mutation of the fields below MUST be accompanied by a version bump
# (`_touch` for external entry points — it also fires `on_change`, the
# fabric's dirty-set hook — or `_bump` for scheduling-internal paths) on
# the same execution path.  The mutation checker (analysis/mutation.py)
# proves this per-commit; the runtime sanitizer (REPRO_SANITIZE=1,
# analysis/sanitizer.py) shadow-hashes the same fields and asserts the
# dynamic counterpart between passes.  docs/static_analysis.md derives
# the invariant from docs/simulator.md's dirty-shell fixpoint argument.
TRACKED_FIELDS = (
    "queues", "requests", "active", "resident", "alloc",
    "_pending_n", "_served_at", "_serve_seq",
)
# Method names that mutate a tracked container/object when called on it
# (or on an alias of it).  Python's stdlib mutators plus this repo's
# domain mutators (Request/BuddyAllocator); reads are everything else.
TRACKED_MUTATORS = (
    "append", "appendleft", "extend", "insert", "pop", "popleft",
    "remove", "discard", "add", "update", "clear", "setdefault",
    "next_chunk", "requeue_chunk", "alloc", "alloc_at", "free",
    "_mark", "_unmark",
)
# External entry points: any mutating path through these must fire
# `_touch` specifically (a bare `_bump` would move the version without
# dirtying the fabric's incremental set — state and schedule would
# still drift apart).
EXTERNAL_MUTATORS = (
    "submit", "abort", "steal_pending", "steal_front", "complete",
)
# Intentionally untracked mutable attributes, each with the invariant
# that makes skipping the version bump safe.  The checker rejects
# mutations of attributes in neither table, so a new field must be
# classified here or in TRACKED_FIELDS before it lands.
UNTRACKED_FIELDS = {
    "_now": "event-time anchor; the fabric advances it for every shell "
            "on every event, dirty or not",
    "_version": "the version counter itself",
    "reserve_history": "sampled per event by Fabric.schedule "
                       "(sample_reserve); a change re-dirties the shell",
    "_reserve_last": "hysteresis anchor of the per-event reservation "
                     "sample; covered by the steal fingerprint directly",
    "_reserve_now": "pass-transient pin, always reset to None",
    "_save_ms_pending": "pass-transient preemption bookkeeping, "
                        "consumed before the pass ends",
    "_preempted": "executor drain queue; never read by scheduling "
                  "decisions",
    "n_preemptions": "reporting counter; never read by scheduling",
    "_tenant_last_ms": "fabric-shared service map; anchors only move "
                       "forward, so a stale next_wake fires early "
                       "(a no-op pass), never late",
    "_shadow": "sanitizer snapshot (analysis/sanitizer.py)",
    "on_change": "constructor/executor wiring, not scheduling state",
    "on_reserve": "observability wiring (repro.obs); fired on reserve "
                  "changes, never read by scheduling decisions",
    "transfer_of": "constructor wiring (fabric hook)",
    "_rid": "constructor wiring (fabric-shared counter)",
    "_aid": "constructor wiring (fabric-shared counter)",
}


class SchedulerState:
    def __init__(self, n_slots: int, registry,
                 policy: PolicyConfig | None = None,
                 cost: CostModel | None = None, speed: float = 1.0,
                 ckpt: CheckpointManager | None = None,
                 ckpt_capable: bool = True, name: str | None = None,
                 arrivals: ArrivalEstimator | None = None,
                 tenant_last_ms: dict | None = None):
        self.alloc = BuddyAllocator(n_slots)
        self.registry = registry
        self.policy = policy or PolicyConfig()
        # relative clock of the hosting shell: this shell serves a chunk
        # in est_chunk_ms / speed (1.0 = the homogeneous seed behavior)
        self.speed = speed
        self.cost = cost or CostModel(registry, self.policy.refine_alpha)
        # checkpoint/restore: a Fabric shares one manager across shells
        # (like the CostModel); a bare state builds its own when the
        # policy asks for checkpointing.  ckpt_capable=False models a
        # shell without context readback: it evicts lossily even when
        # the policy checkpoints elsewhere.
        self.name = name
        self.ckpt_capable = ckpt_capable
        if ckpt is not None:
            self.ckpt = ckpt
        elif self.policy.ckpt:
            self.ckpt = CheckpointManager(registry, self.policy)
        else:
            self.ckpt = None
        if self.policy.reserve_mode not in ("static", "adaptive"):
            raise ValueError(
                f"reserve_mode must be 'static' or 'adaptive', got "
                f"{self.policy.reserve_mode!r}")
        # predictive reservation: a Fabric shares one ArrivalEstimator
        # across shells and feeds it at job admission (so stolen
        # re-submits are never double-counted); a bare state owns its
        # own and observes its direct submits
        if arrivals is not None:
            self.arrivals = arrivals
            self._observe_arrivals = False
        elif self.policy.reserve_mode == "adaptive":
            self.arrivals = ArrivalEstimator(self.policy.arrival_alpha)
            self._observe_arrivals = True
        else:
            self.arrivals = None
            self._observe_arrivals = False
        # effective-reservation trace [(t_ms, slots), ...], recorded on
        # change; the per-pass cache keeps one sizing decision coherent
        # across every placement/preemption/steal of a schedule() pass
        self.reserve_history: list[tuple[float, int]] = []
        self._reserve_last = 0
        self._reserve_now: int | None = None
        self._save_ms_pending = 0.0       # victims' save cost -> preemptor
        # optional rid -> cross-shell transfer cost hook (a Fabric wires
        # it to the stolen sub-request table): a stolen chunk's transfer
        # is overhead, not compute, when estimating evicted progress
        self.transfer_of = None
        self.queues: dict[str, deque[Request]] = {}
        # least-recently-served round robin: new tenants get priority
        self._served_at: dict[str, int] = {}
        self._serve_seq = 0
        # tenant -> last chunk-issue time (ms): the starvation-waiver
        # signal for reservation access (_tenant_starved).  A Fabric
        # shares one map across shells (like the cost model): a tenant
        # being served *anywhere* is not starved, so a stolen
        # sub-request of a served-elsewhere tenant cannot pierce the
        # thief's reserve
        self._tenant_last_ms: dict[str, float] = \
            {} if tenant_last_ms is None else tenant_last_ms
        self.resident: dict[tuple[int, int], tuple[str, int]] = {}
        #        (start, size) -> (module, footprint) for idle ranges too
        self.requests: dict[int, Request] = {}
        self.active: dict[int, Assignment] = {}       # aid -> in-flight
        self.n_preemptions = 0
        self._preempted: list[Assignment] = []        # drained by executor
        self._rid = itertools.count()
        self._aid = itertools.count()
        self._now = 0.0
        # incrementally maintained unissued-chunk count (pending_chunks
        # is on the fabric's per-event dispatch and steal paths)
        self._pending_n = 0
        # monotonically bumped on any mutation that can move the shell's
        # estimated backlog; the fabric keys its _backlog_ms cache on it
        self._version = 0
        # optional zero-arg callback fired on external mutations
        # (submit/abort/complete/steal): a Fabric wires it to its
        # dirty-shell set so direct state access — the daemon's legacy
        # single-shell path — still invalidates incremental scheduling
        self.on_change = None
        # optional (now_ms, slots) callback fired when sample_reserve
        # records a change — observability wiring (repro.obs), never
        # read by any scheduling decision
        self.on_reserve = None
        # REPRO_SANITIZE shadow snapshot (analysis/sanitizer.py):
        # (version, hash of tracked fields) at the last pass boundary
        self._shadow = None

    # -- incremental bookkeeping ----------------------------------------------

    def _bump(self) -> None:
        """A scheduling-internal mutation changed the backlog."""
        self._version += 1

    def _touch(self) -> None:
        """An external mutation changed the shell's scheduling state."""
        self._version += 1
        if self.on_change is not None:
            self.on_change()

    # -- queue management -----------------------------------------------------

    def submit(self, tenant: str, module: str, n_chunks: int,
               payloads=None, now: float = 0.0, priority: int = 0,
               deadline_ms: float | None = None,
               rid: int | None = None) -> Request:
        # a Fabric pre-draws the id from the shared counter so a job's
        # global id equals its primary sub-request's rid on every shell
        rid = next(self._rid) if rid is None else rid
        req = Request(rid, tenant, module, n_chunks, payloads,
                      priority=priority, deadline_ms=deadline_ms,
                      t_submit=now)
        self.requests[rid] = req
        self._now = max(self._now, now)
        if self._observe_arrivals and self.arrivals is not None:
            # bare-state path: a fabric observes at job admission instead
            fp = min(self.registry.module(module).footprints)
            self.arrivals.observe(
                priority, self._now,
                service_ms=self.cost.est_chunk_ms(module, fp),
                footprint=fp)
        if tenant not in self.queues:
            self.queues[tenant] = deque()
            self._served_at.setdefault(tenant, -1)
        self.queues[tenant].append(req)
        self._pending_n += n_chunks
        self._touch()
        return req

    def abort(self, rid: int) -> None:
        """Drop a request's unissued chunks (called after a chunk error).

        In-flight chunks still drain through `complete`; once none remain
        the request is popped from its tenant queue so the tenant is not
        head-of-line blocked by a dead request.
        """
        req = self.requests.get(rid)
        if req is None or req.failed or req.finished:
            return                        # repeat aborts are no-ops
        self._pending_n -= len(req._chunks)  # failed -> pending reads 0
        req.failed = True
        if self.ckpt is not None:
            self.ckpt.drop_request(rid)   # dead chunks never resume
        self._pop_finished(req)
        self._touch()

    def steal_pending(self, rid: int, k: int) -> list[int]:
        """Remove up to `k` unissued chunks from the *tail* of a request's
        pending queue (the chunks furthest from execution — preemption
        victims requeued at the front are taken last) and shrink the
        request accordingly.  Returns the removed chunk ids — the caller
        (a Fabric) re-submits them elsewhere, so each chunk still runs
        exactly once.  A request drained to completion by the steal is
        popped from its tenant queue.

        Checkpointed chunks are never taken from the tail: moving a
        saved context is only worthwhile when restore + transfer +
        remaining wins, which the fabric's gated resume-steal
        (`steal_front`) prices explicitly.
        """
        req = self.requests[rid]
        if req.failed:
            return []
        take = []
        for _ in range(min(k, len(req._chunks))):
            if self.ckpt is not None \
                    and self.ckpt.peek(rid, req._chunks[-1]) is not None:
                break
            take.append(req._chunks.pop())
        req.n_chunks -= len(take)
        self._pending_n -= len(take)
        self._pop_finished(req)
        # unconditional even on an empty take: _pop_finished may still
        # drop a fully-drained request from its tenant queue (a tracked
        # mutation), and a spurious dirty is a no-op reschedule while a
        # missed one diverges from full_reschedule (schedlint mutation)
        self._touch()
        return take

    def steal_front(self, rid: int, k: int) -> list[int]:
        """`steal_pending` from the *front* of the pending queue — where
        preemption victims are requeued.  A fabric uses this to migrate
        a *checkpointed* chunk to another shell when resuming it there
        (restore + transfer + remaining) beats the victim draining it
        locally; the caller re-keys the checkpoint record."""
        req = self.requests[rid]
        if req.failed:
            return []
        take = []
        for _ in range(min(k, len(req._chunks))):
            take.append(req._chunks.popleft())
        req.n_chunks -= len(take)
        self._pending_n -= len(take)
        self._pop_finished(req)
        self._touch()      # unconditional: see steal_pending
        return take

    def pending_chunks(self) -> int:
        """Unissued chunks across every queued request (backlog metric).
        O(1): maintained at every queue mutation (see _pending_chunks_slow
        for the defining recomputation, cross-checked by the test suite)."""
        return self._pending_n

    def _pending_chunks_slow(self) -> int:
        return sum(r.pending for q in self.queues.values() for r in q)

    def _pop_finished(self, req: Request) -> None:
        """Unblock the tenant queue once a request has fully drained.
        Requests can finish out of FIFO order (priorities), so remove by
        identity, not just at the head."""
        if req.finished:
            q = self.queues.get(req.tenant)
            if q is not None:
                try:
                    q.remove(req)
                except ValueError:
                    pass

    def _eligible(self, req: Request) -> bool:
        if req.pending <= 0:
            return False
        # fixed-module scheduling (paper Fig 15a): one module instance per
        # task, chunks strictly sequential -> no replication
        if not self.policy.elastic and req.outstanding > 0:
            return False
        return True

    def _best_request(self, tenant: str,
                      now: float | None = None) -> Optional[Request]:
        """The tenant request the policy would serve next.

        Elastic mode honors per-request priority/deadline anywhere in the
        tenant's queue (an urgent submit overtakes the same tenant's own
        earlier batch work); fixed mode keeps the paper's strict per-tenant
        FIFO so the Fig-15 baseline semantics are unchanged.
        """
        now = self._now if now is None else now
        q = self.queues.get(tenant)
        if not q:
            return None
        if not self.policy.elastic:
            return q[0] if self._eligible(q[0]) else None
        best, bestk = None, None
        for r in q:
            if not self._eligible(r):
                continue
            k = (-self.effective_priority(r, now), r.deadline_at, r.rid)
            if best is None or k < bestk:
                best, bestk = r, k
        return best

    def _pick(self, now: float) -> tuple[Optional[Request], int]:
        """One pass over the tenant queues: the request to serve next
        (highest effective priority, then earliest deadline, then
        least-recently-served tenant — paper Fig 14 when neither is set)
        and the number of contending tenants (the _choose fairness flag).
        """
        best, best_key, contending = None, None, 0
        for t in self.queues:
            r = self._best_request(t, now)
            if r is None:
                continue
            contending += 1
            k = (-self.effective_priority(r, now), r.deadline_at,
                 self._served_at[t])
            if best_key is None or k < best_key:
                best, best_key = r, k
        return best, contending

    # -- priority model --------------------------------------------------------

    def effective_priority(self, req: Request, now: float | None = None) -> int:
        """Base priority plus starvation aging: one level per bound of
        *queueing* delay — the clock resets whenever the request is served,
        so continuously-served work does not age into out-ranking fresh
        high-priority arrivals."""
        now = self._now if now is None else now
        since = req.t_submit if req.t_last_served is None \
            else max(req.t_submit, req.t_last_served)
        waited = max(0.0, now - since)
        bound = max(self.policy.starvation_bound_ms, 1e-9)
        return req.priority + int(waited // bound)

    def _advance_rr(self, tenant: str) -> None:
        self._served_at[tenant] = self._serve_seq
        self._serve_seq += 1

    # -- placement decision -----------------------------------------------------

    def _n_free_ranges(self, size: int, within: int | None = None) -> int:
        """Number of *disjoint* free aligned windows of `size` slots —
        a maximal non-overlapping packing, i.e. how many chunks could
        actually run concurrently.  Buddy alignment yields disjoint
        windows already; the packing scan keeps the count honest for
        any allocator whose aligned starts overlap (counting every free
        start would overstate `conc` in `_choose`'s rate model and skew
        alternative selection toward over-replication)."""
        within = self.alloc.n if within is None else within
        n = 0
        next_free = 0
        for start in self.alloc.aligned_starts(size):
            if start < next_free:
                continue                  # overlaps a counted window
            if start + size <= within and \
                    self.alloc.window_free(start, size):
                n += 1
                next_free = start + size
        return n

    # adaptive reservation shrinks one level only once predicted demand
    # falls this far below the round-down point: a single long gap in
    # an exponential arrival stream must not flap the reservation off
    # right before the stream's next burst (raising is immediate)
    RESERVE_HYSTERESIS = 0.25

    def effective_reserve(self, now: float | None = None) -> int:
        """Slots currently held back for the interactive class: the
        static `reserve_slots` knob, or — `reserve_mode == "adaptive"` —
        the arrival estimator's predicted interactive demand over the
        blocking-chunk + reconfiguration + service horizon (Little's
        law: rate x wait-window x footprint), rounded with downward
        hysteresis and clamped to `[0, reserve_slots_max]`."""
        p = self.policy
        if p.reserve_mode != "adaptive":
            return p.reserve_slots
        if self.arrivals is None or p.reserve_slots_max <= 0:
            return 0
        now = self._now if now is None else now
        demand = self.arrivals.demand_slots(
            p.reserve_priority, now,
            overhead_ms=p.reconfig_penalty_ms, speed=self.speed)
        target = int(demand + 0.5)
        prev = self._reserve_last
        if target < prev and demand > prev - 0.5 - self.RESERVE_HYSTERESIS:
            target = prev               # inside the band: hold
        return min(target, p.reserve_slots_max)

    def sample_reserve(self, now: float) -> int:
        """Evaluate the effective reservation at `now`, updating the
        hysteresis anchor and recording changes in `reserve_history` —
        exactly what the head of a scheduling pass does.  An incremental
        fabric calls this once per event for *every* shell (scheduled or
        not) so the sizing trace and the hysteresis state stay identical
        to the reschedule-everything core; the call is idempotent at a
        fixed (now, estimator state)."""
        r = self.effective_reserve(now)
        if r != self._reserve_last:
            self.reserve_history.append((now, r))
            self._reserve_last = r
            if self.on_reserve is not None:
                self.on_reserve(now, r)
        return r

    def next_wake(self, now: float) -> float:
        """Earliest future instant at which this shell's scheduling
        outcome can change with *no* state mutation in between: a queued
        request crossing a starvation-aging boundary (its effective
        priority steps, reordering _pick / enabling preemption), or a
        tenant crossing the starvation bound (the reservation waiver
        flips on).  With no pending work nothing time-driven can change
        — completions and arrivals dirty the shell through events.  The
        adaptive reservation is *not* a wake source: the fabric samples
        it every event (`sample_reserve`).  Anchors only move forward,
        so a stale stored wake fires early (a no-op reschedule), never
        late."""
        if self._pending_n <= 0:
            return float("inf")
        bound = max(self.policy.starvation_bound_ms, 1e-9)
        wake = float("inf")
        for q in self.queues.values():
            for r in q:
                if r.pending <= 0:
                    continue
                since = r.t_submit if r.t_last_served is None \
                    else max(r.t_submit, r.t_last_served)
                waited = max(0.0, now - since)
                wake = min(wake, since + (int(waited // bound) + 1) * bound)
                last = self._tenant_last_ms.get(r.tenant)
                anchor = r.t_submit if last is None else last
                if anchor + bound > now:
                    wake = min(wake, anchor + bound)
        return wake

    def _current_reserve(self, now: float | None = None) -> int:
        """The pass-coherent reservation size: schedule() pins one value
        per pass; callers outside a pass (fabric dispatch/steal sizing)
        get a fresh computation at *their* clock — a fabric passes its
        own `now` so staleness decay does not lag on a shell whose
        local clock has not advanced in a while."""
        return self.effective_reserve(now) if self._reserve_now is None \
            else self._reserve_now

    def reserve_for_class(self, priority: int, module: str,
                          now: float | None = None) -> int:
        """Slots at the top of the shell held back from a request of
        effective `priority` targeting `module`: 0 for the interactive
        class (priority >= reserve_priority).  A reservation the module
        cannot fit under is *shrunk* to the largest value that still
        leaves it a feasible window — one big-footprint batch module
        must not silently disable interactive protection on the shell."""
        n = self._current_reserve(now)
        if n <= 0 or priority >= self.policy.reserve_priority:
            return 0
        n = min(n, self.alloc.n)
        desc = self.registry.module(module)
        if min(desc.footprints) > self.alloc.n - n:
            n = max(0, self.alloc.n - min(desc.footprints))
        return n

    def _tenant_starved(self, req: Request) -> bool:
        """Has `req`'s tenant gone a full starvation bound with no
        service at all?  A tenant that is merely *backlogged* — its
        earlier requests are being served continuously, on this shell
        or (fabric-shared map) on any other — is not starved, even
        though its queued requests age from submit."""
        last = self._tenant_last_ms.get(req.tenant)
        anchor = req.t_submit if last is None else last
        return (self._now - anchor) >= \
            max(self.policy.starvation_bound_ms, 1e-9)

    def _reserve_for(self, req: Request) -> int:
        # starvation waiver: a request whose effective priority has
        # *aged* into the interactive class AND whose tenant has gone a
        # full starvation bound without any service may use the reserve
        # — the reservation defers batch work, it must not starve a
        # tenant forever.  A backlogged-but-served tenant's aged queue
        # entries do not pierce the reserve (they are making progress;
        # letting them in would poison the very burst headroom the
        # reservation exists for).
        eff = self.effective_priority(req)
        if eff > req.priority and eff >= self.policy.reserve_priority \
                and self._tenant_starved(req):
            return 0
        return self.reserve_for_class(req.priority, req.module)

    def _choose(self, req: Request,
                multi_tenant: bool = False) -> tuple[int, Range, bool] | None:
        """Cost-model choice of implementation alternative + range.

        Rate model: serving min(pending, n_free_ranges(fp)) chunks
        concurrently, each costing est_chunk_ms (+ reconfig penalty unless a
        free range already hosts this module at that footprint).  Pick the
        max-rate option; ties prefer reuse, then the bigger alternative
        (paper: biggest module assumed Pareto-optimal).  elastic=False
        pins everything to the smallest footprint with no replacement.
        """
        desc = self.registry.module(req.module)
        # admission reservation: the top reserve_slots stay out of reach
        # of non-interactive requests (with an unplaceable-forever waiver)
        within = self.alloc.n - self._reserve_for(req)
        fps = [f for f in desc.footprints if self.alloc.can_alloc(f, within)]
        if not self.policy.elastic:
            fps = [f for f in fps if f == min(desc.footprints)]
        if not fps:
            return None
        if multi_tenant or not self.policy.upsize_when_idle:
            # fairness first: smallest footprint, but still reuse if free
            fps = [min(fps)]

        def free_reuse_range(fp: int) -> Range | None:
            for (start, size), (m, f) in self.resident.items():
                if m == req.module and f == fp and size == fp \
                        and start + size <= within \
                        and self.alloc.window_free(start, size):
                    return Range(start, size)
            return None

        best = None  # (rate, reuse, fp, range, reconfigure)
        for fp in fps:
            est = self.cost.est_chunk_ms(req.module, fp, self.speed)
            reuse = free_reuse_range(fp)
            n_avail = self._n_free_ranges(fp, within)
            conc = max(1, min(req.pending, n_avail))
            if reuse is not None:
                t = est
                cand = (conc / max(t, 1e-9), 1, fp, reuse, False)
            else:
                r = self.alloc.find(fp, within)
                if r is None:
                    continue
                prev = self.resident.get((r.start, r.size))
                reconf = prev != (req.module, fp)
                t = est + (
                    self.policy.reconfig_penalty_ms if reconf else 0.0)
                cand = (conc / max(t, 1e-9), 0, fp, r, reconf)
            if best is None or (cand[0], cand[1], cand[2]) > \
                    (best[0], best[1], best[2]):
                best = cand
        if best is None:
            return None
        return best[2], best[3], best[4]

    # -- preemption -------------------------------------------------------------

    def _preempt_for(self, req: Request, now: float,
                     exclude: set[int] = frozenset()) -> bool:
        """Make room for `req`'s smallest implementation alternative by
        evicting in-flight chunks.  Considers each aligned window the
        allocator could place into and evicts only the victims occupying
        the cheapest feasible window — no assignment loses work unless its
        slots are part of the window the preemptor actually gets.
        """
        desc = self.registry.module(req.module)
        need = min(desc.footprints)
        if need > self.alloc.n:
            return False
        eff = self.effective_priority(req, now)
        # a margin below 1 would let equal-priority requests evict each
        # other endlessly within one schedule() pass; clamp it
        margin = max(1, self.policy.preempt_margin)

        def evictable(a: Assignment) -> bool:
            # `exclude` holds assignments issued in the current schedule()
            # pass: aging resets on service, so without it a request served
            # moments ago could be evicted at the same instant it was
            # placed (zero-time churn, and the executor never saw it).
            # A chunk defends at the effective priority it was placed
            # with — NOT its current aged value, which for an in-flight
            # chunk measures *service* time and would grant long chunks
            # growing immunity to exactly the preemption they should face.
            return (a.rid != req.rid and a.aid not in exclude
                    and a.eff + margin <= eff)

        by_slot: dict[int, Assignment] = {}
        for a in self.active.values():
            for i in a.rng.slots:
                by_slot[i] = a
        # a reservation shields the reserved window from non-interactive
        # preemptors just as it does from their ordinary placements
        within = self.alloc.n - self._reserve_for(req)
        best = None  # ((max victim eff, n victims, -newest aid), victims)
        for start in self.alloc.aligned_starts(need):
            if start + need > within:
                continue
            victims: dict[int, Assignment] = {}
            feasible = True
            for i in range(start, start + need):
                if i not in self.alloc.busy:
                    continue
                a = by_slot.get(i)
                if a is None or not evictable(a):
                    feasible = False
                    break
                victims[a.aid] = a
            if not feasible or not victims:
                continue   # window blocked, or free (then _choose had it)
            cost = (max(a.eff for a in victims.values()),
                    len(victims),
                    -max(victims))     # prefer newest chunks: least sunk work
            if best is None or cost < best[0]:
                best = (cost, list(victims.values()))
        if best is None:
            return False
        save_ms = 0.0
        for a in best[1]:
            del self.active[a.aid]
            self.alloc.free(a.rng)
            victim = self.requests[a.rid]
            victim.requeue_chunk(a.chunk)
            if not victim.failed:         # failed -> pending reads 0
                self._pending_n += 1
            self._bump()
            if self.ckpt is not None and self.ckpt_capable \
                    and not victim.failed:
                # snapshot the victim's progress; distinct windows save
                # through their own context ports concurrently, so the
                # preemptor waits for the slowest save, not the sum.
                # A freshly-stolen chunk (frac 1.0 — resumed reruns paid
                # their transfer on the first attempt) spent its
                # transfer cost moving, not computing
                tr = self.transfer_of(a.rid) \
                    if self.transfer_of is not None and a.frac == 1.0 \
                    else 0.0
                est_full = self.cost.est_chunk_ms(a.module, a.footprint,
                                                  self.speed)
                save_ms = max(save_ms, self.ckpt.save(
                    a, now, est_full, speed=self.speed, shell=self.name,
                    extra_overhead_ms=tr))
            # an aborted request whose last in-flight chunk just got
            # evicted drains here, not via complete()
            self._pop_finished(victim)
            self._preempted.append(a)
            self.n_preemptions += 1
        self._save_ms_pending = save_ms
        return True

    def drain_preempted(self) -> list[Assignment]:
        """Victim assignments since the last drain; the executor must cancel
        them (their ranges are already freed and their chunks requeued)."""
        out, self._preempted = self._preempted, []
        return out

    # -- scheduling -------------------------------------------------------------

    def schedule(self, now: float | None = None,
                 placed: set[int] | None = None) -> list[Assignment]:
        """Fill free slots with chunks; called on every event.  Preemption
        victims (if any) are reported through `drain_preempted()`.

        `placed` collects the aids issued this pass (they are exempt from
        preemption — zero-time churn guard); a Fabric passes one set per
        shell across its main and steal-path schedule calls so the guard
        spans the whole fabric scheduling pass, not just this call.
        """
        now = self._now if now is None else max(self._now, now)
        self._now = now
        if sanitizer.SANITIZE:
            # a hash change since the last pass with no version bump is
            # a mutation the dirty-shell elision would have missed
            sanitizer.check(self)
        # pin one reservation size for the whole pass (adaptive mode
        # recomputes from the arrival estimator; static mode returns the
        # knob) so every placement, preemption and steal decision of
        # this pass sees the same value, and record changes for the
        # reserve_history trace
        self._reserve_now = self.sample_reserve(now)
        try:
            return self._schedule_locked(now, placed)
        finally:
            self._reserve_now = None
            if sanitizer.SANITIZE:
                sanitizer.rearm(self)

    def _schedule_locked(self, now: float,
                         placed: set[int] | None) -> list[Assignment]:
        out = []
        placed = set() if placed is None else placed
        while True:
            req, contending = self._pick(now)
            if req is None:
                break
            multi_tenant = contending > 1
            choice = self._choose(req, multi_tenant)
            if choice is None and self.policy.preemptive \
                    and self._preempt_for(req, now, exclude=placed):
                choice = self._choose(req, multi_tenant)
            if choice is None:
                self._save_ms_pending = 0.0
                break
            fp, rng, reconf = choice
            self.alloc.alloc_at(rng)
            # evict overlapped stale residents, then record the new one
            for key in [k for k in self.resident
                        if not (k[0] + k[1] <= rng.start
                                or rng.start + rng.size <= k[0])]:
                del self.resident[key]
            self.resident[(rng.start, rng.size)] = (req.module, fp)
            chunk = req.next_chunk()
            self._pending_n -= 1
            self._bump()
            frac, restore_ms = 1.0, 0.0
            if self.ckpt is not None:
                rec = self.ckpt.take(req.rid, chunk)
                if rec is not None:
                    # resume from the checkpoint: run only the remaining
                    # fraction, paying the priced restore cost up front
                    frac = rec.remaining
                    restore_ms = self.ckpt.restore_cost_ms(
                        req.module, fp, self.speed)
            save_ms = self._save_ms_pending
            self._save_ms_pending = 0.0
            if save_ms > 0.0 and reconf:
                # the victims' context save overlaps the preemptor's own
                # reconfiguration (readback and configuration ports are
                # distinct); only the excess delays the preemptor
                save_ms = max(0.0, save_ms
                              - self.policy.reconfig_penalty_ms)
            a = Assignment(req.rid, chunk, req.module, fp,
                           rng, reconf, aid=next(self._aid),
                           eff=self.effective_priority(req, now),
                           t_start=now, frac=frac,
                           restore_ms=restore_ms, save_ms=save_ms)
            self.active[a.aid] = a
            out.append(a)
            placed.add(a.aid)
            req.t_last_served = now
            self._tenant_last_ms[req.tenant] = now
            self._advance_rr(req.tenant)
        return out

    def complete(self, a: Assignment, now: float = 0.0) -> bool:
        """Record a finished chunk.  Returns False (a no-op) when the
        assignment was preempted before completion — the executor must then
        discard the result; the chunk re-runs under a fresh assignment."""
        if a.aid not in self.active:
            return False
        del self.active[a.aid]
        self.alloc.free(a.rng)
        self._now = max(self._now, now)
        req = self.requests[a.rid]
        req.done += 1
        if req.complete:
            req.t_finish = now
        self._pop_finished(req)
        self._touch()
        return True
