"""Resource-elastic space-time scheduler (paper section 4.4).

Pure policy core, shared by the discrete-event simulator (tests, Fig-15
benchmark) and the live daemon executor:

  - round-robin between tenants at acceleration-request granularity;
  - each request carries independent data-parallel *chunks* (work-groups);
  - module REPLICATION: chunks of one request run on many slots;
  - module REPLACEMENT: when adjacent slots are free, a bigger
    implementation alternative is placed on the merged range;
  - REUSE: a range still hosting the right module skips reconfiguration;
  - cooperative run-to-completion at chunk granularity.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Any, Optional

from repro.core.allocator import BuddyAllocator, Range
from repro.core.registry import ModuleDescriptor


@dataclasses.dataclass
class Request:
    rid: int
    tenant: str
    module: str
    n_chunks: int
    payloads: list | None = None          # live mode: per-chunk args
    issued: int = 0                       # chunks handed to slots
    done: int = 0
    t_submit: float = 0.0
    t_finish: float | None = None

    @property
    def pending(self) -> int:
        return self.n_chunks - self.issued

    @property
    def outstanding(self) -> int:
        return self.issued - self.done

    @property
    def complete(self) -> bool:
        return self.done >= self.n_chunks


@dataclasses.dataclass(frozen=True)
class Assignment:
    rid: int
    chunk: int
    module: str
    footprint: int
    rng: Range
    reconfigure: bool                     # False -> reused resident module


@dataclasses.dataclass
class PolicyConfig:
    # prefer the largest implementation alternative when the system is
    # otherwise idle (paper: "attempts to use the biggest module")
    upsize_when_idle: bool = True
    # estimated reconfiguration cost relative to a chunk (cost model)
    reconfig_penalty_ms: float = 5.0
    elastic: bool = True                  # False -> fixed 1-slot scheduling


class SchedulerState:
    def __init__(self, n_slots: int, registry, policy: PolicyConfig | None = None):
        self.alloc = BuddyAllocator(n_slots)
        self.registry = registry
        self.policy = policy or PolicyConfig()
        self.queues: dict[str, deque[Request]] = {}
        # least-recently-served round robin: new tenants get priority
        self._served_at: dict[str, int] = {}
        self._serve_seq = 0
        self.resident: dict[tuple[int, int], tuple[str, int]] = {}
        #        (start, size) -> (module, footprint) for idle ranges too
        self.requests: dict[int, Request] = {}
        self._rid = itertools.count()

    # -- queue management -----------------------------------------------------

    def submit(self, tenant: str, module: str, n_chunks: int,
               payloads=None, now: float = 0.0) -> Request:
        rid = next(self._rid)
        req = Request(rid, tenant, module, n_chunks, payloads,
                      t_submit=now)
        self.requests[rid] = req
        if tenant not in self.queues:
            self.queues[tenant] = deque()
            self._served_at.setdefault(tenant, -1)
        self.queues[tenant].append(req)
        return req

    def _eligible(self, req: Request) -> bool:
        if req.pending <= 0:
            return False
        # fixed-module scheduling (paper Fig 15a): one module instance per
        # task, chunks strictly sequential -> no replication
        if not self.policy.elastic and req.outstanding > 0:
            return False
        return True

    def _tenants_pending(self) -> list[str]:
        return [t for t, q in self.queues.items()
                if q and self._eligible(q[0])]

    def _next_request(self) -> Optional[Request]:
        """Round-robin across tenants at request granularity (paper Fig 14):
        the least-recently-served pending tenant goes next."""
        pending = self._tenants_pending()
        if not pending:
            return None
        t = min(pending, key=lambda t: self._served_at[t])
        return self.queues[t][0]

    def _advance_rr(self, tenant: str) -> None:
        self._served_at[tenant] = self._serve_seq
        self._serve_seq += 1

    # -- placement decision -----------------------------------------------------

    def _n_free_ranges(self, size: int) -> int:
        n = 0
        for start in range(0, self.alloc.n, size):
            if all(i not in self.alloc.busy
                   for i in range(start, start + size)):
                n += 1
        return n

    def _choose(self, req: Request) -> tuple[int, Range, bool] | None:
        """Cost-model choice of implementation alternative + range.

        Rate model: serving min(pending, n_free_ranges(fp)) chunks
        concurrently, each costing est_chunk_ms (+ reconfig penalty unless a
        free range already hosts this module at that footprint).  Pick the
        max-rate option; ties prefer reuse, then the bigger alternative
        (paper: biggest module assumed Pareto-optimal).  elastic=False
        pins everything to the smallest footprint with no replacement.
        """
        desc = self.registry.module(req.module)
        fps = [f for f in desc.footprints if self.alloc.can_alloc(f)]
        if not self.policy.elastic:
            fps = [f for f in fps if f == min(desc.footprints)]
        if not fps:
            return None
        multi_tenant = len(self._tenants_pending()) > 1
        if multi_tenant or not self.policy.upsize_when_idle:
            # fairness first: smallest footprint, but still reuse if free
            fps = [min(fps)]

        def free_reuse_range(fp: int) -> Range | None:
            for (start, size), (m, f) in self.resident.items():
                if m == req.module and f == fp and size == fp:
                    r = Range(start, size)
                    if all(i not in self.alloc.busy for i in r.slots):
                        return r
            return None

        best = None  # (rate, reuse, fp, range, reconfigure)
        for fp in fps:
            impl = desc.impl_for(fp)
            reuse = free_reuse_range(fp)
            n_avail = self._n_free_ranges(fp)
            conc = max(1, min(req.pending, n_avail))
            if reuse is not None:
                t = impl.est_chunk_ms
                cand = (conc / max(t, 1e-9), 1, fp, reuse, False)
            else:
                r = self.alloc.find(fp)
                if r is None:
                    continue
                prev = self.resident.get((r.start, r.size))
                reconf = prev != (req.module, fp)
                t = impl.est_chunk_ms + (
                    self.policy.reconfig_penalty_ms if reconf else 0.0)
                cand = (conc / max(t, 1e-9), 0, fp, r, reconf)
            if best is None or (cand[0], cand[1], cand[2]) > \
                    (best[0], best[1], best[2]):
                best = cand
        if best is None:
            return None
        return best[2], best[3], best[4]

    def schedule(self) -> list[Assignment]:
        """Fill free slots with chunks; called on every event."""
        out = []
        while True:
            req = self._next_request()
            if req is None:
                break
            choice = self._choose(req)
            if choice is None:
                break
            fp, rng, reconf = choice
            self.alloc.alloc_at(rng)
            # evict overlapped stale residents, then record the new one
            for key in [k for k in self.resident
                        if not (k[0] + k[1] <= rng.start
                                or rng.start + rng.size <= k[0])]:
                del self.resident[key]
            self.resident[(rng.start, rng.size)] = (req.module, fp)
            out.append(Assignment(req.rid, req.issued, req.module, fp,
                                  rng, reconf))
            req.issued += 1
            self._advance_rr(req.tenant)
        return out

    def complete(self, a: Assignment, now: float = 0.0) -> None:
        self.alloc.free(a.rng)
        req = self.requests[a.rid]
        req.done += 1
        if req.complete:
            req.t_finish = now
            q = self.queues[req.tenant]
            if q and q[0].rid == a.rid:
                q.popleft()
