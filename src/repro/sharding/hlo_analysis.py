"""Post-compile HLO analysis: collective-byte accounting.

collective_bytes is not reported by compiled.cost_analysis(); we parse the
(partitioned, per-device) HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
HLO prints operand types inline, e.g.::

    %all-reduce.1 = bf16[128,512]{1,0} all-reduce(bf16[128,512]{1,0} %x), ...

Sizes are PER-DEVICE (partitioned program).  NOTE: ops inside while-loop
bodies appear once; the dry-run therefore derives totals from *unrolled*
small-depth compiles and extrapolates (see launch/dryrun.py).
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVES) +
    r")(-start)?\(")
_TYPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)"
                      r"\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dtype]


def _operand_section(line: str) -> str:
    """Text inside the outermost parens of the op call on this line."""
    i = line.find("(")
    if i < 0:
        return ""
    depth, j = 0, i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    return line[i + 1:j]


_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")


def _group_size(line: str, default: int = 16) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    return len(m.group(1).split(","))


def collective_bytes(hlo_text: str) -> dict:
    """Per-opcode operand bytes of collectives (per device).

    `total` follows the assignment formula (sum of operand sizes).
    `wire_total` additionally estimates bytes actually serialised through a
    device's links (ring algorithms, group size g parsed per op):
      all-reduce 2(g-1)/g x operand; reduce-scatter/all-to-all/permute
      (g-1)/g x operand; all-gather (g-1) x operand (operand = one shard).
    """
    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    wire: dict = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        section = _operand_section(line[m.start():])
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _TYPE_RE.findall(section))
        g = _group_size(line)
        if op == "all-reduce":
            w = 2 * (g - 1) / max(g, 1) * nbytes
        elif op == "all-gather":
            w = (g - 1) * nbytes
        else:
            w = (g - 1) / max(g, 1) * nbytes
        out[op] += nbytes
        wire[op] += w
        counts[op] += 1
    return {"per_op": dict(out), "counts": dict(counts),
            "wire_per_op": {k: int(v) for k, v in wire.items()},
            "total": sum(out.values()),
            "wire_total": int(sum(wire.values()))}


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+ = ((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"([\w\-]+)\(")


def _result_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _TYPE_RE.findall(type_str))


def bytes_by_op(hlo_text: str, top: int = 15) -> dict:
    """Aggregate (output + operand) bytes per opcode over the optimised HLO.

    Approximates HBM traffic attribution: for fusions the I/O is what hits
    HBM; elementwise ops inside fusions don't appear.  Loop bodies counted
    once (use on unrolled cost compiles).
    """
    from collections import defaultdict
    out_bytes: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        type_str, opcode = m.group(1), m.group(2)
        if opcode in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast"):
            continue
        if opcode == "dynamic-update-slice":
            # in-place (donated) update: traffic = read+write of the update
            # piece (operand 1), not the whole buffer
            ops = _TYPE_RE.findall(_operand_section(line[m.end() - 1:]))
            if len(ops) >= 2:
                piece = _shape_bytes(*ops[1])
                out_bytes[opcode] += 2 * piece
                counts[opcode] += 1
                continue
        total = _result_bytes(type_str)
        total += sum(_shape_bytes(d, s) for d, s in
                     _TYPE_RE.findall(_operand_section(line[m.end() - 1:])))
        out_bytes[opcode] += total
        counts[opcode] += 1
    ranked = sorted(out_bytes.items(), key=lambda kv: -kv[1])[:top]
    return {op: {"bytes": b, "count": counts[op]} for op, b in ranked}


# Op classes whose I/O genuinely hits HBM on a TPU compile.  The CPU
# backend's optimisation pipeline leaves elementwise chains (convert /
# multiply / select / broadcast...) unfused, so raw cost_analysis
# "bytes accessed" wildly overcounts HBM traffic vs what the TPU compiler
# (or our Pallas kernels) would produce; those ops fuse into their
# producers/consumers on TPU and are excluded here.
HBM_REAL_OPS = frozenset({
    "dot", "convolution", "fusion", "copy", "transpose",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "sort", "cumsum",
    "reduce-window", "while",
})


def hbm_model_bytes(hlo_text: str) -> int:
    """Fusion-aware HBM-traffic estimate (see HBM_REAL_OPS)."""
    per_op = bytes_by_op(hlo_text, top=10 ** 6)
    return sum(v["bytes"] for op, v in per_op.items()
               if op in HBM_REAL_OPS and op != "while")


def cost_analysis_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def memory_stats_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    return {k: int(getattr(ma, k)) for k in keys}
