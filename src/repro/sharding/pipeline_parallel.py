"""GPipe-style pipeline parallelism over a mesh axis (default: "pod").

For >=4-pod topologies the slow inter-pod ICI favours pipeline parallelism
over DP (only stage-boundary activations cross pods instead of full
gradients).  This module provides a self-contained schedule:

  - the model is split into S stages (contiguous layer groups) whose params
    carry a leading stage axis sharded over `axis`;
  - the global batch is split into M microbatches;
  - at schedule tick t, stage s processes microbatch (t - s); activations
    move to the next stage via jax.lax.ppermute (point-to-point over the
    pod links — exactly the collective you want crossing pods);
  - bubbles are masked; outputs are valid on the last stage and broadcast.

stage_fn must be shape-preserving on the activation ([b, ...] -> [b, ...]),
which holds for residual-stack stages; embedding/unembedding stay outside
(replicated over the stage axis).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe_apply(stage_fn: Callable, stage_params, x, *, mesh,
                axis: str = "pod", n_micro: int | None = None,
                extra_spec=P()):
    """Run S pipeline stages over x: equivalent to sequentially applying
    stage_fn with stage_params[s] for s in range(S).

    stage_params: pytree with leading stage axis (size S) on every leaf.
    x: [B, ...] activations (replicated over `axis`).
    Returns [B, ...] (replicated over `axis`).
    """
    s_stages = mesh.shape[axis]
    b = x.shape[0]
    n_micro = n_micro or max(s_stages, 1)
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro
    micro = x.reshape(n_micro, mb, *x.shape[1:])
    ticks = n_micro + s_stages - 1
    perm = [(i, (i + 1) % s_stages) for i in range(s_stages)]

    p_spec = jax.tree.map(lambda _: P(axis), stage_params)

    def body(params, xs):
        sid = jax.lax.axis_index(axis)
        local = jax.tree.map(lambda a: a[0], params)   # this stage's params

        def tick(t, carry):
            fifo, outs = carry
            mb_idx = t - sid
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 pulls its microbatch; others take the permuted carry
            inp0 = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            inp = jnp.where(sid == 0, inp0, fifo)
            h = stage_fn(local, inp)
            h = jnp.where(active[..., None, None] if h.ndim > 1 else active,
                          h, fifo)
            # collect finished microbatches on the last stage
            out_idx = jnp.clip(t - (s_stages - 1), 0, n_micro - 1)
            write = (sid == s_stages - 1) & active
            cur = jax.lax.dynamic_index_in_dim(outs, out_idx, 0,
                                               keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(write, h, cur), out_idx, 0)
            # hand off to the next stage (pod-to-pod point-to-point)
            fifo = jax.lax.ppermute(h, axis, perm)
            return fifo, outs

        fifo0 = jnp.zeros_like(micro[0])
        outs0 = jnp.zeros_like(xs)
        _, outs = jax.lax.fori_loop(0, ticks, tick, (fifo0, outs0))
        # broadcast the last stage's outputs to every stage replica
        is_last = (sid == s_stages - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * is_last, axis)
        return outs

    outs = jax.shard_map(
        body, mesh=mesh, in_specs=(p_spec, extra_spec), out_specs=extra_spec,
        check_vma=False)(stage_params, micro)
    return outs.reshape(b, *x.shape[1:])


def split_stages(stacked_params, n_stages: int):
    """[L, ...] layer-stacked params -> [S, L/S, ...] stage-stacked."""
    def f(a):
        l = a.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])
    return jax.tree.map(f, stacked_params)
