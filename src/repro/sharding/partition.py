"""Logical-axis -> mesh-axis partitioning rules.

Model code tags params/inputs with *logical* axis names (see
repro.models.api.param_specs / repro.models.io.input_axis_specs).  A rule set
maps logical names to mesh axes; this module turns axes pytrees into
PartitionSpec / NamedSharding pytrees.

Axis vocabulary:
  params:  layers, embed (fsdp-able), embed_nofsdp, q_proj, kv_proj, mlp,
           vocab, expert, expert_mlp, inner, heads_ssm
  data:    batch, seq, seq_kv, kv_heads_kv
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


MeshAxes = Any  # str | tuple[str, ...] | None


@dataclasses.dataclass(frozen=True)
class AxisRules:
    rules: Mapping[str, MeshAxes]

    def get(self, name: str | None) -> MeshAxes:
        if name is None:
            return None
        if name not in self.rules:
            raise KeyError(f"no rule for logical axis {name!r}")
        return self.rules[name]

    @property
    def batch_axes(self):
        """Raw rule value for "batch" — a valid PartitionSpec entry
        (None | str | tuple of str)."""
        return self.get("batch")


def flat_axes(value) -> tuple:
    """Flatten a rule value into a tuple of mesh-axis names (drops None)."""
    if value is None:
        return ()
    if isinstance(value, (tuple, list)):
        out = []
        for v in value:
            out.extend(flat_axes(v))
        return tuple(out)
    return (value,)


def make_rules(kind: str, multi_pod: bool = False,
               overrides: Mapping[str, MeshAxes] | None = None) -> AxisRules:
    """kind: "train" | "serve"."""
    batch = ("pod", "data") if multi_pod else ("data",)
    base = {
        "layers": None,
        "batch": batch,
        "seq": None,
        "q_proj": "model",
        "kv_proj": "model",
        "heads": "model",
        "seq_attn": None,
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "expert_mlp": None,
        "inner": "model",
        "heads_ssm": "model",
        "embed_nofsdp": None,
        "embed_act": None,
    }
    if kind == "train":
        base["embed"] = "data"      # ZeRO-3 / FSDP over the data axis
        base["seq"] = "model"       # Megatron-style sequence parallelism
        base["seq_kv"] = None       # caches unused in training
        base["kv_heads_kv"] = None
    elif kind == "train_fsdp":
        # pure-FSDP (ZeRO-3 over the WHOLE mesh, no tensor parallelism):
        # when tokens-per-device is large, per-layer activation AG/AR of
        # TP+SP costs ~5x tokens x d_model, while pure FSDP only moves
        # params (~3x params/layer). Best for dense archs at train_4k's
        # global batch; MoE keeps TP/EP (expert axis needs "model").
        batch_all = batch + ("model",)
        base.update({
            "batch": batch_all,
            "embed": ("data", "model"),
            "seq": None,
            "q_proj": None, "kv_proj": None, "heads": None,
            "mlp": None, "vocab": None, "inner": None,
            "heads_ssm": None, "expert": None, "expert_mlp": None,
            "seq_kv": None, "kv_heads_kv": None,
        })
    elif kind == "serve":
        base["embed"] = None        # latency path: TP only
        # KV caches are SEQUENCE-sharded over the TP axis (works for any
        # kv_heads vs TP degree; see layers.sharded_cache_attention)
        base["seq_kv"] = "model"
        base["kv_heads_kv"] = None
    else:
        raise ValueError(kind)
    if overrides:
        base.update(overrides)
    return AxisRules(base)


def to_pspec(axes: tuple, rules: AxisRules) -> P:
    return P(*(rules.get(a) for a in axes))


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def tree_pspecs(axes_tree, rules: AxisRules):
    return jax.tree.map(lambda a: to_pspec(a, rules), axes_tree,
                        is_leaf=_is_axes)


def tree_shardings(axes_tree, mesh: Mesh, rules: AxisRules):
    return jax.tree.map(lambda a: NamedSharding(mesh, to_pspec(a, rules)),
                        axes_tree, is_leaf=_is_axes)


# ---------------------------------------------------------------------------
# activation constraint context (used sparsely inside model code)
# ---------------------------------------------------------------------------

_ACTIVE: list[AxisRules | None] = [None]


class use_rules:
    def __init__(self, rules: AxisRules | None):
        self.rules = rules

    def __enter__(self):
        _ACTIVE.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE.pop()


def active_rules() -> AxisRules | None:
    return _ACTIVE[-1]


def constrain(x: jax.Array, axes: tuple) -> jax.Array:
    """Apply with_sharding_constraint if a rule set is active."""
    rules = _ACTIVE[-1]
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(x, to_pspec(axes, rules))
