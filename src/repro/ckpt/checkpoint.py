"""Async, sharded, atomically-published checkpoints with elastic restore.

Layout:  <dir>/step_<N>/   arrays.npz  +  tree.json  (+ meta.json)
         <dir>/step_<N>.tmp.<pid>      staging, atomically renamed.

Properties:
  - async: device->host transfer happens on the caller thread (cheap), the
    file write on a background thread; `wait()` joins outstanding saves.
  - elastic restore: restore() takes target shardings — a checkpoint saved
    on one mesh/sharding restores onto any other (the FOS *replacement*
    primitive applied to training jobs).
  - atomic publish: readers only ever see complete step_<N> directories.
  - retention: keep_last prunes old steps after successful publish.

Single-host container note: arrays are written whole (process_allgather is
the identity here).  At real multi-host scale each host would write only
its addressable shards keyed by global slice — the format (per-leaf keys +
tree.json) is already shaped for that extension.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np


_NATIVE = {np.dtype(t) for t in
           ("float64", "float32", "float16", "int64", "int32", "int16",
            "int8", "uint64", "uint32", "uint16", "uint8", "bool")}


def _flatten(state) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """npz can't hold ml_dtypes (bf16/fp8); store a raw byte view plus the
    true dtype in the manifest."""
    flat, _ = jax.tree.flatten_with_path(state)
    arrays, dtypes = {}, {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        dtypes[key] = str(arr.dtype)
        if arr.dtype not in _NATIVE:
            arr = arr.view(np.uint8)
        arrays[key] = arr
    return arrays, dtypes


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_last: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._pending: list[threading.Thread] = []

    # -- save -------------------------------------------------------------

    def save(self, step: int, state, meta: dict | None = None,
             blocking: bool = False) -> None:
        arrays, dtypes = _flatten(state)
        meta = dict(meta or {}, step=step, time=time.time(),
                    dtypes=dtypes)

        def _write():
            tmp = self.dir / f"step_{step}.tmp.{os.getpid()}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / "arrays.npz", **arrays)
            (tmp / "meta.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._prune()

        t = threading.Thread(target=_write, daemon=True)
        t.start()
        self._pending.append(t)
        if blocking:
            t.join()

    def wait(self) -> None:
        for t in self._pending:
            t.join()
        self._pending.clear()

    def _prune(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore -----------------------------------------------------------

    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir() and not p.name.endswith(
                tuple(f".tmp.{s}" for s in [""])) and ".tmp." not in p.name)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like_state, shardings=None):
        """Restore into the structure of like_state (abstract or concrete).
        `shardings`: optional matching pytree of NamedShardings — the
        restore target may use a completely different mesh/partitioning
        than the save did (elastic restore)."""
        path = self.dir / f"step_{step}" / "arrays.npz"
        data = np.load(path)
        saved_dtypes = self.meta(step).get("dtypes", {})
        flat, treedef = jax.tree.flatten_with_path(like_state)
        sh_flat = (jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "memory_kind"))
            if shardings is not None else [None] * len(flat))
        leaves = []
        for (pathk, like), sh in zip(flat, sh_flat):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in pathk)
            arr = data[key]
            true_dtype = saved_dtypes.get(key, str(arr.dtype))
            if str(arr.dtype) != true_dtype:   # raw byte view round-trip
                import ml_dtypes  # noqa: F401 - registers dtype names
                arr = arr.view(np.dtype(true_dtype))
            assert tuple(arr.shape) == tuple(like.shape), \
                f"{key}: ckpt {arr.shape} vs target {like.shape}"
            arr = arr.astype(like.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        return jax.tree.unflatten(jax.tree.structure(like_state), leaves)

    def meta(self, step: int) -> dict:
        return json.loads(
            (self.dir / f"step_{step}" / "meta.json").read_text())
