"""Fault tolerance: watchdog, fault injection, restart-from-checkpoint.

At 1000+-node scale the failure model is: a host stops making progress
(hardware fault, preemption, network partition) or stalls (straggler).
The training driver wraps its step loop with:

  - Heartbeat/Watchdog: detects a stalled step and raises in the driver
    (on a real cluster this triggers the coordinator's re-mesh path);
  - FaultInjector: deterministic fault injection for tests/drills;
  - run_with_restarts: supervisor that restarts the loop from the latest
    checkpoint, optionally on a *smaller* slot allocation (elastic shrink
    = FOS withdrawing a PR region).
"""
from __future__ import annotations

import threading
import time
from typing import Callable


class StepTimeout(RuntimeError):
    pass


class InjectedFault(RuntimeError):
    pass


class Watchdog:
    """Raises (via callback) if no heartbeat arrives within `timeout_s`.

    Straggler mitigation at dry-run scale: the driver treats a timeout
    like a failed worker — re-checkpoint boundary restart, possibly with
    the slow pod dropped from the mesh.
    """

    def __init__(self, timeout_s: float, on_timeout: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._fired = False
        self._thread = threading.Thread(target=self._watch, daemon=True)

    def start(self):
        self._last = time.monotonic()
        self._thread.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2)

    def _watch(self):
        while not self._stop.wait(self.timeout_s / 4):
            if time.monotonic() - self._last > self.timeout_s:
                self._fired = True
                self.on_timeout()
                return

    @property
    def fired(self) -> bool:
        return self._fired


class FaultInjector:
    """Deterministic fault injection: fail at a given step (once)."""

    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step
        self._done = False

    def check(self, step: int):
        if (self.fail_at_step is not None and not self._done
                and step == self.fail_at_step):
            self._done = True
            raise InjectedFault(f"injected fault at step {step}")


def run_with_restarts(run_fn: Callable[[int], int], *, max_restarts: int = 3,
                      log=print) -> tuple[int, int]:
    """Supervise run_fn(start_step) -> final_step, restarting on faults.

    Returns (final_step, n_restarts).  run_fn is responsible for restoring
    from its checkpoint manager at start_step.
    """
    restarts = 0
    step = 0
    while True:
        try:
            step = run_fn(step)
            return step, restarts
        except (InjectedFault, StepTimeout) as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            log(f"[fault] {e}; restart #{restarts} from latest checkpoint")
