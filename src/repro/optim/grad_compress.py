"""Gradient compression for slow (inter-pod) links: int8 + error feedback.

Per-row (last-axis) absmax int8 quantisation.  Error feedback keeps the
quantisation residual locally and adds it to the next step's gradient, so
the compression bias vanishes over steps (1-bit/deep-compression folklore;
the EF-SGD convergence argument applies).

Usage inside a train step (applied to the gradient pytree *before* the
optimizer; psum/collective happens on the int8 payload under shard_map in
a real multi-pod run — in the GSPMD train step we model it as
quantise->dequantise which preserves the numerics of compress+AR because
all-reduce of int8 payloads is linear in the dequantised domain only
approximately; see DESIGN.md for the accounting):

    (grads, ef) = compress_grads(grads, ef_state)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (q [int8], scale [.., 1] f32) along the last axis."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_grads(grads, ef_state):
    """Error-feedback int8 round trip on every gradient leaf.

    Returns (compressed_grads, new_ef_state).
    """
    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        if g.ndim < 1 or g.size < 256:
            return gf.astype(g.dtype), jnp.zeros_like(e)   # tiny: skip
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    pairs = jax.tree.map(leaf, grads, ef_state)
    new_grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_ef


def compression_ratio(grads) -> float:
    """Bytes on the wire: int8 payload + f32 row scales vs f32."""
    total = 0
    compressed = 0
    for g in jax.tree.leaves(grads):
        n = g.size
        total += n * 4
        if g.ndim < 1 or n < 256:
            compressed += n * 4
        else:
            rows = n // g.shape[-1]
            compressed += n * 1 + rows * 4
    return compressed / total
