"""AdamW with decoupled weight decay, global-norm clipping and LR schedules.

Pure JAX (no optax dependency).  Optimizer state is a dict {"m","v","count"}
whose m/v mirror the param pytree (and therefore shard identically).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"        # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * (1 - frac)
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def _decay_mask(params) -> list[bool]:
    """True where weight decay applies: 2D+ matrices, not norms/biases."""
    flat, _ = jax.tree.flatten_with_path(params)
    mask = []
    for path, leaf in flat:
        name = str(path[-1]).lower()
        is_norm_or_bias = any(t in name for t in
                              ("norm", "bias", "b_", "bq", "bv", "bo",
                               "ln", "a_log", "d_skip"))
        mask.append(leaf.ndim >= 2 and not is_norm_or_bias)
    return mask


def init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g * scale, grads)
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    mask = _decay_mask(params)

    new_p, new_m, new_v = [], [], []
    for g, p, m, v, wd in zip(flat_g, flat_p, flat_m, flat_v, mask):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if wd:
            upd = upd + cfg.weight_decay * pf
        pf = pf - lr * upd
        new_p.append(pf.astype(p.dtype))
        new_m.append(m.astype(p.dtype))
        new_v.append(v.astype(p.dtype))

    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "count": count},
            {"grad_norm": gnorm, "lr": lr})
