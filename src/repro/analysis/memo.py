"""Checker 2: memo-fingerprint completeness.

The incremental core elides recomputation through memo caches — the
backlog estimate (`Fabric._backlog_cache`), the failed-steal
fingerprint (`Fabric._steal_fail`), the demand memo
(`ArrivalEstimator._demand`).  Each is sound only if its key covers
*every* piece of versioned state the cached computation reads: one
uncovered read and a stale value survives a state change, and the
byte-identity with the reschedule-everything core is gone.

The contracts live next to the caches as `MEMO_CONTRACTS` literals:

    MEMO_CONTRACTS = (
        {"name": "backlog_ms", "func": "Fabric._backlog_ms",
         "cache": "_backlog_cache", "key": ("state", "cost"),
         "folded": {}},
        ...)

`key` lists the version tokens the cache key covers (see
analysis/config.py VERSIONED for the token model); `folded` declares
tokens that are covered *indirectly* — e.g. the steal fingerprint
never keys on the arrival estimator directly, but every shell's
reservation is resampled from it each event, so arrival changes are
folded into `_reserve_last` — each with a written justification.

The checker walks the cached computation and everything it calls
(cross-module, cycle-safe), classifies every attribute read through
the declared receiver types, and reports any read whose token the key
does not cover.  Reads through receivers the type map cannot resolve
(locals holding tuple payloads etc.) are skipped unless the attribute
name is on the Request/Assignment surface — the realistic regression
is a new read of `self.*` or a typed shell/state attribute, and those
always classify.  Calls *into* another declared contract count as
reading that contract's key tokens.
"""
from __future__ import annotations

import ast

from repro.analysis import config
from repro.analysis.walker import Finding, Project, SourceModule, Typer

CHECKER = "memo"

KNOWN_TOKENS = frozenset({
    "state", "cost", "arrivals", "reserve", "now", "tenant_service",
    "args", "net",
})


class _ReadCollector:
    """Transitive attribute-read classification for one contract."""

    def __init__(self, project: Project):
        self.project = project
        self.contract_keys: dict[tuple[str, str], tuple] = {}
        # the cache attribute itself is memo storage, not versioned
        # state: reading it is what makes the function a memo
        self.cache_attrs: set[tuple[str, str]] = set()
        for c in project.memo_contracts:
            cls, _, meth = c["func"].rpartition(".")
            self.contract_keys[(cls, meth)] = tuple(c["key"])
            self.cache_attrs.add((cls, c["cache"]))
        self._done: set[tuple[str, str]] = set()
        # (token, label, file, line)
        self.reads: list[tuple] = []

    def collect(self, cls: str, method: str) -> None:
        key = (cls, method)
        if key in self._done:
            return
        self._done.add(key)
        hit = self.project.find_method(cls, method)
        if hit is None:
            return
        module, fn = hit
        typer = Typer(self.project, cls)
        for node in sorted(
                [n for n in ast.walk(fn)
                 if isinstance(n, (ast.Assign, ast.For))],
                key=lambda n: n.lineno):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    typer.assign(t, node.value)
            else:
                typer.assign(node.target, node.iter)
        now_params = {a.arg for a in fn.args.args if a.arg == "now"}
        # an Attribute that is a call's func is a method *invocation*,
        # handled by the Call branch (descend / contract tokens), not
        # an attribute read
        call_funcs = {id(n.func) for n in ast.walk(fn)
                      if isinstance(n, ast.Call)}
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.ctx, ast.Load) \
                    and id(node) not in call_funcs:
                self._classify(module, cls, typer, node)
            elif isinstance(node, ast.Name) \
                    and isinstance(node.ctx, ast.Load) \
                    and node.id in now_params:
                self.reads.append(("now", f"parameter '{node.id}'",
                                   module.path, node.lineno))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                recv_cls = typer.of(node.func.value)
                if recv_cls is None:
                    continue
                callee = (recv_cls, node.func.attr)
                if callee in self.contract_keys and callee != key:
                    for tok in self.contract_keys[callee]:
                        self.reads.append((
                            tok,
                            f"call into memoized "
                            f"{recv_cls}.{node.func.attr} (keys on "
                            f"'{tok}')", module.path, node.lineno))
                elif self._is_mutator(callee):
                    # a state-mutating call marks the *non-cached*
                    # outcome (e.g. the steal success path re-submits;
                    # the fingerprint caches failed scans only): its
                    # body computes no part of the memoized value, and
                    # the mutation checker separately guarantees it
                    # bumps the versions the key reads
                    continue
                elif self.project.find_method(*callee):
                    self.collect(*callee)

    def _is_mutator(self, callee: tuple[str, str]) -> bool:
        cls, name = callee
        if name in ("_touch", "_bump"):
            return True
        if cls in self.project.state_classes \
                and name in self.project.external:
            return True
        return cls == "CheckpointManager" \
            and name in self.project.ckpt_mutators

    def _classify(self, module: SourceModule, cls: str, typer: Typer,
                  node: ast.Attribute) -> None:
        recv_cls = typer.of(node.value)
        attr = node.attr
        label = f"{recv_cls or '<untyped>'}.{attr}"
        if recv_cls is not None:
            if recv_cls in self.project.state_classes \
                    and attr in self.project.tracked:
                self.reads.append(("state", label, module.path,
                                   node.lineno))
                return
            if (recv_cls, attr) in self.project.types:
                return                        # typed traversal edge
            if (recv_cls, attr) in self.cache_attrs:
                return                        # the memo storage itself
            if (recv_cls, attr) in self.project.versioned:
                tok = self.project.versioned[(recv_cls, attr)]
                if tok is not None:
                    self.reads.append((tok, label, module.path,
                                       node.lineno))
                return
            if attr in config.REQUEST_ATTRS:
                self.reads.append(("state", label, module.path,
                                   node.lineno))
                return
            if attr.startswith("__"):
                return
            self.reads.append(
                (f"?", label, module.path, node.lineno))
            return
        if attr in config.REQUEST_ATTRS:
            self.reads.append(("state", label, module.path,
                               node.lineno))


def check_memo(project: Project) -> list[Finding]:
    findings = project.pragma_findings(CHECKER)
    for contract in project.memo_contracts:
        cmod = project.modules[contract["_module"]]
        cls, _, meth = contract["func"].rpartition(".")
        name = contract.get("name", contract["func"])
        hit = project.find_method(cls, meth)
        if hit is None:
            findings.append(Finding(
                CHECKER, cmod.path, 1,
                f"memo contract '{name}' names {contract['func']}, "
                f"which does not exist"))
            continue
        bad_tokens = set(contract["key"]) - KNOWN_TOKENS
        for tok in sorted(bad_tokens):
            findings.append(Finding(
                CHECKER, cmod.path, 1,
                f"memo contract '{name}' keys on unknown token "
                f"'{tok}' (known: {sorted(KNOWN_TOKENS)})"))
        folded = contract.get("folded", {}) or {}
        for tok, why in sorted(folded.items()):
            if not str(why).strip():
                findings.append(Finding(
                    CHECKER, cmod.path, 1,
                    f"memo contract '{name}' folds token '{tok}' "
                    f"without a justification — folding is an "
                    f"argument, write it down"))
        covered = set(contract["key"]) | set(folded) | {"args"}
        col = _ReadCollector(project)
        col.collect(cls, meth)
        seen = set()
        for tok, label, path, line in col.reads:
            if tok in covered or (tok, label, line) in seen:
                continue
            seen.add((tok, label, line))
            if project.pragma(project.modules[
                    _mod_of(project, path)], line, CHECKER) is not None:
                continue
            if tok == "?":
                findings.append(Finding(
                    CHECKER, path, line,
                    f"memoized '{name}' ({contract['func']}) reads "
                    f"{label}, which has no versioned-state "
                    f"classification — add it to "
                    f"analysis/config.VERSIONED (or a SCHEDLINT_"
                    f"VERSIONED declaration) so the key can be "
                    f"checked against it"))
            else:
                findings.append(Finding(
                    CHECKER, path, line,
                    f"memoized '{name}' ({contract['func']}) reads "
                    f"{label} (token '{tok}') but its cache key "
                    f"{contract['key']} does not cover '{tok}': a "
                    f"stale hit survives that state changing "
                    f"(docs/static_analysis.md, invariant 2)"))
    return findings


def _mod_of(project: Project, path: str) -> str:
    for name, m in project.modules.items():
        if m.path == path:
            return name
    raise KeyError(path)
