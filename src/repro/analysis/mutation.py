"""Checker 1: mutation-tracking completeness.

The dirty-shell invariant (docs/simulator.md) holds only if every
mutation of a tracked `SchedulerState` field is accompanied by a
version bump on the same execution path — otherwise the incremental
fabric keeps treating the shell as a scheduling fixpoint and silently
diverges from `full_reschedule`.  This checker proves the lexical side
of that contract:

  1. **registry completeness** — every attribute a state-class method
     assigns must be declared, either in `TRACKED_FIELDS` or in
     `UNTRACKED_FIELDS` with a written justification.  An undeclared
     field is a finding: nobody has argued why the dirty-set can
     ignore it.
  2. **path coverage** — for every *public* method (of the state class
     and of every orchestrating class, e.g. `Fabric`), no tracked
     mutation event may reach the method's exit on a path with no
     `_touch()`/`_bump()`.  Private helpers may expose mutations;
     they are checked at their public callers through interprocedural
     summaries.
  3. **external discipline** — methods listed in `EXTERNAL_MUTATORS`
     are called *between* scheduling passes (by executors, the fabric,
     tests); a bare `_bump()` there moves the version without firing
     `on_change`, so the fabric's dirty set never learns of the
     change.  These methods are re-checked under a stricter mode where
     only `_touch()` clears.

Intentional exceptions carry a `# schedlint: ok(mutation) <reason>`
pragma on the offending line.
"""
from __future__ import annotations

import ast

from repro.analysis.walker import Finding, PathEngine, Project, Typer

CHECKER = "mutation"


def _is_public(name: str) -> bool:
    return not name.startswith("_") or name == "schedule"


def _store_targets(fn: ast.FunctionDef):
    """Yield (node, attr) for every `self.X = ...`-shaped store."""
    for node in ast.walk(fn):
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = node.targets
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                tgt = el
                # `self.X[k] = v` mutates X just as `self.X = v` does
                while isinstance(tgt, ast.Subscript):
                    tgt = tgt.value
                if isinstance(tgt, ast.Attribute) \
                        and isinstance(tgt.value, ast.Name) \
                        and tgt.value.id == "self":
                    yield node, tgt.attr


def _registry_findings(project: Project, module, cls) -> list[Finding]:
    """Rule 1: every assigned state attribute is declared somewhere."""
    out = []
    known = set(project.tracked) | set(project.untracked)
    for name, fn in module.methods(cls).items():
        if name == "__init__":
            continue          # constructors build fresh, unshared state
        for node, attr in _store_targets(fn):
            if attr in known:
                continue
            if project.pragma(module, node.lineno, CHECKER) is not None:
                continue
            out.append(Finding(
                CHECKER, module.path, node.lineno,
                f"{cls}.{name} assigns undeclared field "
                f"'self.{attr}': add it to TRACKED_FIELDS (the "
                f"dirty-shell invariant depends on it) or to "
                f"UNTRACKED_FIELDS with a justification"))
    return out


def _exposure_findings(project: Project, module, cls, method,
                       engine: PathEngine, mode_msg: str) \
        -> list[Finding]:
    out = []
    for ev in sorted(engine.summary(cls, method).exposed,
                     key=lambda e: (e.line, e.field)):
        if project.pragma(module, ev.line, CHECKER) is not None:
            continue
        via = f" ({ev.note})" if ev.note else ""
        out.append(Finding(
            CHECKER, module.path, ev.line,
            f"{cls}.{method}: mutation of tracked field '{ev.field}' "
            f"on '{ev.recv}'{via} can reach the method's exit "
            f"{mode_msg} — the fabric would keep treating the shell "
            f"as a scheduling fixpoint (docs/static_analysis.md, "
            f"invariant 1)"))
    return out


def check_mutation(project: Project) -> list[Finding]:
    findings = project.pragma_findings(CHECKER)
    if not project.tracked:
        return findings               # nothing declared, nothing to do
    bump = PathEngine(project, mode="bump")
    touch = PathEngine(project, mode="touch")
    for module in project.modules.values():
        for cls in module.classes:
            is_state = cls in project.state_classes
            if is_state:
                findings += _registry_findings(project, module, cls)
            for name in module.methods(cls):
                if name.startswith("__") or name in ("_touch", "_bump"):
                    continue
                # rule 2: public entry points leave no uncovered path
                if _is_public(name) or (is_state and
                                        name in project.external):
                    findings += _exposure_findings(
                        project, module, cls, name, bump,
                        "with no _touch()/_bump() on that path")
                # rule 3: external entry points must fire on_change
                if is_state and name in project.external:
                    findings += _exposure_findings(
                        project, module, cls, name, touch,
                        "with no _touch() on that path (a bare _bump "
                        "moves the version but never fires on_change, "
                        "so the fabric's dirty set misses it)")
    # declared external mutators must exist on some state class
    for name in sorted(project.external):
        if not any(project.find_method(cls, name)
                   for cls in project.state_classes):
            for m in project.modules.values():
                if "EXTERNAL_MUTATORS" in m.decls:
                    findings.append(Finding(
                        CHECKER, m.path, 1,
                        f"EXTERNAL_MUTATORS declares '{name}' but no "
                        f"state class defines it"))
                    break
    return findings
