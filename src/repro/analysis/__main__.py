"""CLI: `python -m repro.analysis [paths...]`.

With no arguments, checks the incremental scheduling core
(src/repro/core/*.py) plus the observability package
(src/repro/obs/*.py — its tracer/recorder are declared sim modules
in-file and must stay as deterministic as the fabric feeding them).
Prints one line per finding and exits 1 if any survive the
pragmas/allowlist, 0 on a clean run — cheap enough (pure stdlib, no
jax, <1s) to gate CI and pre-commit on.
"""
from __future__ import annotations

import sys
from pathlib import Path

from repro.analysis import analyze

CORE = Path(__file__).resolve().parents[1] / "core"
OBS = Path(__file__).resolve().parents[1] / "obs"


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = [p for p in argv if not p.startswith("-")]
    if not paths:
        paths = sorted(str(p) for d in (CORE, OBS)
                       for p in d.glob("*.py")
                       if p.name != "__init__.py")
    findings = analyze(paths)
    for f in findings:
        print(f)
    n = len(findings)
    print(f"schedlint: {n} finding{'s' if n != 1 else ''} "
          f"across {len(paths)} file{'s' if len(paths) != 1 else ''}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
