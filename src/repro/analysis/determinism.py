"""Checker 3: determinism lint for simulator-path modules.

Golden-trace byte-identity and the incremental/full equivalence
properties assume the scheduling core is a pure function of its event
stream.  Five things silently break that:

  - **wall-clock** — `time.*` mixed into virtual time;
  - **randomness** — `random` / `jax.random` / `numpy.random` in a
    decision path;
  - **id-order** — `id()` used in ordering or keys (address-dependent);
  - **environ** — `os.environ` / `os.getenv` reads steering behavior;
  - **set-iter** — iterating (or `sum`ming, `list`ing, `pop`ping) an
    unordered set where order can reach a decision.  Membership tests,
    `sorted()`, `len()`, `min`/`max`/`any`/`all` are fine.

Sim-path modules (`config.SIM_MODULES` or an in-file
`SCHEDLINT_SIM = True`) get no module-level exceptions: an intentional
violation must sit on the offending line as a
`# schedlint: ok(determinism) <reason>` pragma, visible in review.
Non-sim core modules are scanned too, against the
`config.DETERMINISM_ALLOWLIST` (module, rule) entries — the daemon
*is* the wall-clock binding, kernel benchmarking measures real time —
so a new kind of nondeterminism in those files still surfaces.
"""
from __future__ import annotations

import ast

from repro.analysis import config
from repro.analysis.walker import Finding, Project, SourceModule

CHECKER = "determinism"

_RANDOM_MODULES = {"random"}
_RANDOM_ATTRS = {("jax", "random"), ("numpy", "random"),
                 ("np", "random")}
_SET_MAKERS = {"set", "frozenset"}
_SET_METHODS = {"copy", "union", "intersection", "difference",
                "symmetric_difference"}
# order-sensitive consumers of an iterable
_ORDER_SINKS = {"list", "tuple", "enumerate", "iter", "sum", "map",
                "filter", "reversed"}
_ORDER_SAFE = {"sorted", "len", "min", "max", "any", "all", "bool",
               "frozenset", "set"}


def _annotation_is_set(node) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in _SET_MAKERS:
            return True
    return False


class _ModuleScan:
    def __init__(self, project: Project, module: SourceModule,
                 strict: bool):
        self.project = project
        self.module = module
        self.strict = strict           # sim path: pragmas only
        self.findings: list[Finding] = []
        # (class, attr) known to hold sets; None class = module global
        self.set_attrs: set[tuple] = set()
        # classes whose `self` IS a set (subclasses of set)
        self.set_selves: set[str] = set()

    # -- reporting ------------------------------------------------------------

    def report(self, rule: str, line: int, msg: str) -> None:
        if self.project.pragma(self.module, line, CHECKER) is not None:
            return
        if not self.strict and (self.module.name, rule) \
                in config.DETERMINISM_ALLOWLIST:
            return
        self.findings.append(Finding(
            CHECKER, self.module.path, line, f"[{rule}] {msg}"))

    # -- pre-pass: where do sets live? ----------------------------------------

    def index_sets(self) -> None:
        for cls_name, cls in self.module.classes.items():
            for base in cls.bases:
                if isinstance(base, ast.Name) \
                        and base.id in _SET_MAKERS:
                    self.set_selves.add(cls_name)
            for node in ast.walk(cls):
                tgt = None
                if isinstance(node, ast.AnnAssign) \
                        and node.annotation is not None \
                        and _annotation_is_set(node.annotation):
                    tgt = node.target
                elif isinstance(node, ast.Assign) \
                        and self._makes_set(node.value, {}):
                    tgt = node.targets[0] \
                        if len(node.targets) == 1 else None
                if isinstance(tgt, ast.Attribute) \
                        and isinstance(tgt.value, ast.Name) \
                        and tgt.value.id == "self":
                    self.set_attrs.add((cls_name, tgt.attr))

    def _makes_set(self, expr, locals_: dict) -> bool:
        if isinstance(expr, (ast.Set, ast.SetComp)):
            return True
        if isinstance(expr, ast.Call):
            f = expr.func
            if isinstance(f, ast.Name) and f.id in _SET_MAKERS:
                return True
            if isinstance(f, ast.Attribute) \
                    and f.attr in _SET_METHODS:
                return self._is_set(f.value, locals_, None)
        if isinstance(expr, ast.BinOp) and isinstance(
                expr.op, (ast.BitOr, ast.BitAnd, ast.Sub)):
            return self._is_set(expr.left, locals_, None) \
                or self._is_set(expr.right, locals_, None)
        return False

    def _is_set(self, expr, locals_: dict, cls: str | None) -> bool:
        if isinstance(expr, ast.Name):
            if expr.id == "self" and cls in self.set_selves:
                return True
            return locals_.get(expr.id, False)
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self":
            return any((c, expr.attr) in self.set_attrs
                       for c in self.module.classes)
        return self._makes_set(expr, locals_)

    # -- the scan -------------------------------------------------------------

    def run(self) -> None:
        self.index_sets()
        for node in self.module.tree.body:
            self._imports(node)
        for node in ast.walk(self.module.tree):
            self._imports(node)
            if isinstance(node, ast.Attribute):
                self._attr(node)
            elif isinstance(node, ast.Call):
                self._call(node)
        # set iteration needs per-function local tracking; scan every
        # function exactly once, under its owning class if any
        owner: dict[int, str] = {}
        for cls_name, cls in self.module.classes.items():
            for fn in (n for n in ast.walk(cls)
                       if isinstance(n, ast.FunctionDef)):
                owner[id(fn)] = cls_name
        for fn in (n for n in ast.walk(self.module.tree)
                   if isinstance(n, ast.FunctionDef)):
            self._scan_fn(owner.get(id(fn)), fn)

    def _imports(self, node) -> None:
        if isinstance(node, ast.Import):
            for a in node.names:
                root = a.name.split(".")[0]
                if root == "time":
                    self.report("wall-clock", node.lineno,
                                "imports `time` — virtual-time code "
                                "must receive clocks as arguments")
                elif root in _RANDOM_MODULES:
                    self.report("randomness", node.lineno,
                                f"imports `{a.name}`")
        elif isinstance(node, ast.ImportFrom):
            root = (node.module or "").split(".")[0]
            if root == "time":
                self.report("wall-clock", node.lineno,
                            "imports from `time`")
            elif root in _RANDOM_MODULES:
                self.report("randomness", node.lineno,
                            f"imports from `{node.module}`")

    def _attr(self, node: ast.Attribute) -> None:
        v = node.value
        if isinstance(v, ast.Name):
            if (v.id, node.attr) in _RANDOM_ATTRS:
                self.report("randomness", node.lineno,
                            f"uses `{v.id}.{node.attr}`")
            elif v.id == "os" and node.attr in ("environ", "getenv"):
                self.report("environ", node.lineno,
                            f"reads `os.{node.attr}` — behavior must "
                            f"not depend on ambient environment")

    def _call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Name) and f.id == "id" \
                and len(node.args) == 1:
            self.report("id-order", node.lineno,
                        "calls `id()` — object addresses vary per run; "
                        "key on stable ids (rid/aid/name) instead")

    def _scan_fn(self, cls_name, fn: ast.FunctionDef) -> None:
        locals_: dict[str, bool] = {}
        for node in sorted(
                (n for n in ast.walk(fn)
                 if isinstance(n, (ast.Assign, ast.AnnAssign))),
                key=lambda n: n.lineno):
            tgts = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            val = node.value
            if val is None:
                continue
            for t in tgts:
                if isinstance(t, ast.Name):
                    locals_[t.id] = self._is_set(val, locals_,
                                                 cls_name)
                elif isinstance(t, ast.Tuple) \
                        and isinstance(val, ast.Tuple) \
                        and len(t.elts) == len(val.elts):
                    for te, ve in zip(t.elts, val.elts):
                        if isinstance(te, ast.Name):
                            locals_[te.id] = self._is_set(
                                ve, locals_, cls_name)
        for node in ast.walk(fn):
            if isinstance(node, (ast.For, ast.AsyncFor)) \
                    and self._is_set(node.iter, locals_, cls_name):
                self.report(
                    "set-iter", node.lineno,
                    "iterates an unordered set — order can reach a "
                    "scheduling decision; iterate `sorted(...)` or an "
                    "insertion-ordered dict instead")
            elif isinstance(node, (ast.ListComp, ast.SetComp,
                                   ast.DictComp, ast.GeneratorExp)):
                for gen in node.generators:
                    if self._is_set(gen.iter, locals_, cls_name):
                        self.report(
                            "set-iter", node.lineno,
                            "comprehension over an unordered set")
            elif isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) \
                        and f.id in _ORDER_SINKS and node.args \
                        and self._is_set(node.args[0], locals_,
                                         cls_name):
                    self.report(
                        "set-iter", node.lineno,
                        f"`{f.id}()` over an unordered set — the "
                        f"result order is hash-dependent")
                elif isinstance(f, ast.Attribute) and f.attr == "pop" \
                        and not node.args \
                        and self._is_set(f.value, locals_, cls_name):
                    self.report(
                        "set-iter", node.lineno,
                        "`set.pop()` removes an arbitrary element")


def check_determinism(project: Project) -> list[Finding]:
    findings = project.pragma_findings(CHECKER)
    for module in project.modules.values():
        scan = _ModuleScan(project, module,
                           strict=module.name in project.sim_modules)
        scan.run()
        findings += scan.findings
    return findings
