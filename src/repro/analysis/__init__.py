"""schedlint: scheduler-invariant static analysis (pure stdlib).

Three AST-based checkers over the incremental scheduling core, plus a
runtime sanitizer companion (`sanitizer.py`, `REPRO_SANITIZE=1`):

  - **mutation** (mutation.py): every mutation of a tracked
    `SchedulerState` field (`scheduler.TRACKED_FIELDS`) must have a
    dominating `_touch()`/`_bump()` version bump on the same path;
  - **memo** (memo.py): every declared memo cache (`MEMO_CONTRACTS`)
    must key on every piece of versioned state its computation reads;
  - **determinism** (determinism.py): simulator-path modules must be
    free of wall-clock, randomness, `id()` ordering, `os.environ`
    reads and unordered-set iteration.

The contracts live *in the checked code* as plain literal constants
(`TRACKED_FIELDS`, `MEMO_CONTRACTS`, ...) and are extracted from the
AST — running the checkers imports nothing from `repro.core`, so
`python -m repro.analysis` works in seconds on a bare CPython with no
jax installed.  docs/static_analysis.md documents the invariants and
the allowlist policy.
"""
from __future__ import annotations

from repro.analysis.walker import Finding, Project
from repro.analysis.determinism import check_determinism
from repro.analysis.memo import check_memo
from repro.analysis.mutation import check_mutation

__all__ = ["Finding", "Project", "analyze", "check_determinism",
           "check_memo", "check_mutation"]


def analyze(paths, sim_modules=None) -> list[Finding]:
    """Run all three checkers over `paths`; findings sorted by file/line."""
    project = Project(paths, sim_modules=sim_modules)
    findings = (check_mutation(project) + check_memo(project)
                + check_determinism(project))
    return sorted(findings, key=lambda f: (f.file, f.line, f.checker))
