"""Shared AST machinery for schedlint (pure stdlib, no repro imports).

The checkers never import the code they check: contracts are plain
literal constants (`TRACKED_FIELDS`, `MEMO_CONTRACTS`, ...) extracted
from the parsed source, so `python -m repro.analysis` runs on a bare
CPython.  This module provides:

  - `Project`: parses a set of files, indexes classes and methods
    across modules, extracts the in-code contract declarations, and
    collects `# schedlint: ok(<checker>) <reason>` pragmas;
  - `PathEngine`: a small path-sensitive abstract interpreter over one
    function body.  It tracks, per execution path, the set of tracked
    mutation events and whether a version bump happened anywhere on
    that path (a bump on a path covers every mutation of that path —
    within one method there is no interleaved cache read, so bump
    order inside the method does not matter; see
    docs/static_analysis.md).  Aliases of tracked fields through
    locals (`req = self.requests[rid]`), subscripts, `.get()`/
    `.values()` chains, tuple unpacking and `for` targets are
    followed; receiver classes are inferred from a declared type map
    so cross-object mutations (`vst.steal_pending(...)` in fabric
    methods) resolve to interprocedural method summaries.

Soundness posture: the engine is deliberately conservative where the
AST runs out of information (unknown calls are ignored, merged branch
states keep every possibility) and coarse where precision would not
pay (clearing is per-path, not per-receiver).  The runtime sanitizer
(sanitizer.py) covers the dynamic gap.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, Optional

# declarations extracted from checked sources when present
_DECL_NAMES = (
    "TRACKED_FIELDS", "TRACKED_MUTATORS", "EXTERNAL_MUTATORS",
    "UNTRACKED_FIELDS", "TRACKED_CLASS", "MEMO_CONTRACTS",
    "CKPT_MUTATORS", "SCHEDLINT_SIM", "SCHEDLINT_TYPES",
    "SCHEDLINT_VERSIONED", "SCHEDLINT_SAFE_ATTRS",
)

_PRAGMA_RE = re.compile(
    r"#\s*schedlint:\s*ok\((?P<checker>[a-z]+)\)\s*(?P<reason>.*)")

# bounded path explosion: beyond this many states per program point the
# engine merges pairwise (union events, AND cleared) — conservative
_MAX_STATES = 64


@dataclasses.dataclass(frozen=True)
class Finding:
    checker: str
    file: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: [{self.checker}] {self.message}"


class SourceModule:
    """One parsed file: AST, class index, declarations, pragmas."""

    def __init__(self, path: str):
        self.path = str(path)
        self.name = Path(path).stem
        src = Path(path).read_text()
        self.tree = ast.parse(src, filename=self.path)
        self.classes: dict[str, ast.ClassDef] = {}
        self.decls: dict[str, object] = {}
        for node in self.tree.body:
            if isinstance(node, ast.ClassDef):
                self.classes[node.name] = node
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and node.targets[0].id in _DECL_NAMES:
                try:
                    self.decls[node.targets[0].id] = \
                        ast.literal_eval(node.value)
                except ValueError:
                    pass               # non-literal: not a declaration
        # line -> {checker: reason}; "" reason is itself reported.
        # A pragma on its own (comment) line attaches forward to the
        # next code line, so multi-line justifications work:
        #     # schedlint: ok(determinism) reason, possibly
        #     # wrapping onto further comment lines
        #     for i in tuple(self): ...
        self.pragmas: dict[int, dict[str, str]] = {}
        lines = src.splitlines()
        for i, line in enumerate(lines, start=1):
            m = _PRAGMA_RE.search(line)
            if m is None:
                continue
            entry = {m.group("checker"): m.group("reason").strip()}
            self.pragmas.setdefault(i, {}).update(entry)
            if line.lstrip().startswith("#"):
                for j in range(i, len(lines)):
                    nxt = lines[j].strip()
                    if nxt and not nxt.startswith("#"):
                        self.pragmas.setdefault(j + 1, {}).update(entry)
                        break

    def methods(self, cls: str) -> dict[str, ast.FunctionDef]:
        node = self.classes.get(cls)
        if node is None:
            return {}
        return {n.name: n for n in node.body
                if isinstance(n, ast.FunctionDef)}


class Project:
    """A set of parsed modules plus the merged contract declarations."""

    def __init__(self, paths: Iterable[str], sim_modules=None):
        from repro.analysis import config
        self.modules: dict[str, SourceModule] = {}
        for p in paths:
            m = SourceModule(p)
            self.modules[m.name] = m
        # merged declarations: config defaults, then in-file literals
        self.tracked: tuple = config.TRACKED_FALLBACK
        self.mutators: set[str] = set(config.MUTATORS_FALLBACK)
        self.external: set[str] = set()
        self.untracked: dict[str, str] = {}
        self.state_classes: set[str] = set()
        self.memo_contracts: list[dict] = []
        self.ckpt_mutators: set[str] = set()
        self.types: dict = dict(config.TYPE_HINTS)
        self.versioned: dict = dict(config.VERSIONED)
        self.safe_attrs: dict = {k: set(v)
                                 for k, v in config.SAFE_ATTRS.items()}
        declared_sim = set()
        for m in self.modules.values():
            d = m.decls
            if "TRACKED_FIELDS" in d:
                self.tracked = tuple(d["TRACKED_FIELDS"])
                self.state_classes.add(
                    d.get("TRACKED_CLASS", config.STATE_CLASS))
            if "TRACKED_MUTATORS" in d:
                self.mutators = set(d["TRACKED_MUTATORS"])
            if "EXTERNAL_MUTATORS" in d:
                self.external |= set(d["EXTERNAL_MUTATORS"])
            if "UNTRACKED_FIELDS" in d:
                self.untracked.update(d["UNTRACKED_FIELDS"])
            if "CKPT_MUTATORS" in d:
                self.ckpt_mutators |= set(d["CKPT_MUTATORS"])
            if "MEMO_CONTRACTS" in d:
                for c in d["MEMO_CONTRACTS"]:
                    self.memo_contracts.append(
                        dict(c, _module=m.name))
            if d.get("SCHEDLINT_SIM"):
                declared_sim.add(m.name)
            for key, val in (d.get("SCHEDLINT_TYPES") or {}).items():
                self.types[tuple(key.split("."))
                           if "." in key else key] = val
            for key, val in (d.get("SCHEDLINT_VERSIONED") or {}).items():
                cls, attr = key.split(".")
                self.versioned[(cls, attr)] = val
            for key in (d.get("SCHEDLINT_SAFE_ATTRS") or ()):
                cls, attr = key.split(".")
                self.safe_attrs.setdefault(cls, set()).add(attr)
        if not self.state_classes:
            self.state_classes = {config.STATE_CLASS}
        if sim_modules is not None:
            self.sim_modules = set(sim_modules)
        else:
            self.sim_modules = (set(config.SIM_MODULES)
                                & set(self.modules)) | declared_sim

    # -- cross-module lookups -------------------------------------------------

    def find_class(self, cls: str) -> Optional[tuple[SourceModule,
                                                     ast.ClassDef]]:
        for m in self.modules.values():
            if cls in m.classes:
                return m, m.classes[cls]
        return None

    def find_method(self, cls: str, name: str) \
            -> Optional[tuple[SourceModule, ast.FunctionDef]]:
        hit = self.find_class(cls)
        if hit is None:
            return None
        m, _ = hit
        fn = m.methods(cls).get(name)
        return None if fn is None else (m, fn)

    def pragma(self, module: SourceModule, line: int,
               checker: str) -> Optional[str]:
        """The justification of a `# schedlint: ok(checker)` pragma on
        `line` (or the line above it), else None."""
        for ln in (line, line - 1):
            entry = module.pragmas.get(ln)
            if entry and checker in entry:
                return entry[checker]
        return None

    def pragma_findings(self, checker: str) -> list[Finding]:
        """Pragmas with an empty justification are findings themselves:
        the allowlist policy requires every exception to say why."""
        out = []
        for m in self.modules.values():
            for line, entry in m.pragmas.items():
                if entry.get(checker) == "":
                    out.append(Finding(
                        checker, m.path, line,
                        "schedlint pragma without a justification — "
                        "every intentional exception must say why it "
                        "is safe (docs/static_analysis.md)"))
        return out


# -- type inference -----------------------------------------------------------

class Typer:
    """Coarse receiver-class inference from the declared type map.

    `project.types` maps a bare name ("st") or an (owner-class, attr)
    pair (("Fabric", "states") for container element types) to a class
    name.  Locals pick up types flow-insensitively from assignments.
    """

    def __init__(self, project: Project, owner: str):
        self.project = project
        self.owner = owner
        self.locals: dict[str, str] = {}

    def of(self, expr: ast.AST) -> Optional[str]:
        if isinstance(expr, ast.Name):
            if expr.id == "self":
                return self.owner
            if expr.id in self.locals:
                return self.locals[expr.id]
            hint = self.project.types.get(expr.id)
            return hint if isinstance(hint, str) else None
        if isinstance(expr, ast.Attribute):
            base = self.of(expr.value)
            if base is not None:
                hint = self.project.types.get((base, expr.attr))
                if isinstance(hint, str):
                    return hint
            return None
        if isinstance(expr, ast.Subscript):
            # elements of a typed container share its declared type
            return self.of(expr.value)
        if isinstance(expr, ast.Call):
            f = expr.func
            if isinstance(f, ast.Attribute) and f.attr in (
                    "get", "values", "pop", "setdefault", "items"):
                return self.of(f.value)
        return None

    def assign(self, target: ast.AST, value: ast.AST) -> None:
        if isinstance(target, ast.Name):
            t = self.of(value)
            if t is not None:
                self.locals[target.id] = t
            else:
                self.locals.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            if isinstance(value, (ast.Tuple, ast.List)) \
                    and len(value.elts) == len(target.elts):
                for tgt, val in zip(target.elts, value.elts):
                    self.assign(tgt, val)
            else:
                # `for k, v in d.items()` / unpacking one typed source:
                # give every element the source's (element) type —
                # coarse, but keys are rarely dereferenced
                for tgt in target.elts:
                    self.assign(tgt, value)


# -- the path engine ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Event:
    """One tracked mutation on some execution path."""
    field: str
    line: int
    recv: str          # source-ish receiver label, for messages
    note: str = ""     # e.g. "via self._pop_finished"


@dataclasses.dataclass(frozen=True)
class PathState:
    events: frozenset    # of Event
    cleared: bool        # a version bump happened on this path


@dataclasses.dataclass
class Summary:
    """Interprocedural method summary under one clearing mode."""
    exposed: frozenset           # Events reaching exit on uncleared paths
    always_clears: bool          # every path through bumps the version
    returns_alias: frozenset     # tracked fields the return may alias


def _recv_label(expr: ast.AST) -> str:
    try:
        return ast.unparse(expr)
    except Exception:                              # pragma: no cover
        return "<expr>"


class PathEngine:
    """Path-sensitive mutation/clearing analysis for one class's
    methods, with interprocedural summaries (memoized, cycle-safe).

    `mode` selects what counts as clearing: "bump" accepts `_touch`,
    `_bump` and a direct `_version` augassign; "touch" accepts only
    `_touch` (the external-entry-point rule — a bare bump moves the
    version without firing `on_change`, so the fabric's dirty set
    never learns of the mutation).
    """

    def __init__(self, project: Project, mode: str = "bump"):
        self.project = project
        self.mode = mode
        self._summaries: dict[tuple[str, str], Summary] = {}
        self._in_progress: set[tuple[str, str]] = set()

    # -- summaries ------------------------------------------------------------

    def summary(self, cls: str, method: str) -> Summary:
        key = (cls, method)
        if key in self._summaries:
            return self._summaries[key]
        if key in self._in_progress:       # recursion: be conservative
            return Summary(frozenset(), False, frozenset())
        if method in ("_touch",):
            s = Summary(frozenset(), True, frozenset())
        elif method in ("_bump",):
            s = Summary(frozenset(), self.mode == "bump", frozenset())
        else:
            hit = self.project.find_method(cls, method)
            if hit is None:
                return Summary(frozenset(), False, frozenset())
            self._in_progress.add(key)
            try:
                s = self._analyze(cls, method, hit[1])
            finally:
                self._in_progress.discard(key)
        self._summaries[key] = s
        return s

    def _analyze(self, cls: str, method: str,
                 fn: ast.FunctionDef) -> Summary:
        walk = _FunctionWalk(self, cls, fn)
        exits = walk.run()
        exposed = frozenset(
            ev for s in exits if not s.cleared for ev in s.events)
        always = all(s.cleared for s in exits) and bool(exits)
        return Summary(exposed, always, frozenset(walk.return_alias))


class _FunctionWalk:
    """One function body under the path engine."""

    def __init__(self, engine: PathEngine, cls: str,
                 fn: ast.FunctionDef):
        self.engine = engine
        self.project = engine.project
        self.cls = cls
        self.fn = fn
        self.typer = Typer(engine.project, cls)
        # local name -> frozenset of tracked field names it may alias
        self.aliases: dict[str, frozenset] = {}
        self.return_alias: set = set()
        self.exit_states: list[PathState] = []

    # -- driver ---------------------------------------------------------------

    def run(self) -> list[PathState]:
        states = {PathState(frozenset(), False)}
        states = self.stmts(self.fn.body, states)
        self.exit_states.extend(states)     # fall-through exit
        return self.exit_states

    def _merge(self, states: set) -> set:
        if len(states) <= _MAX_STATES:
            return states
        all_events = frozenset(
            ev for s in states for ev in s.events)
        return {PathState(all_events, all(s.cleared for s in states))}

    def stmts(self, body, states: set) -> set:
        for stmt in body:
            states = self.stmt(stmt, states)
            if not states:
                break                        # all paths exited
        return states

    # -- statements -----------------------------------------------------------

    def stmt(self, node: ast.stmt, states: set) -> set:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return states                    # nested defs: out of scope
        if isinstance(node, ast.Return):
            if node.value is not None:
                states = self.expr(node.value, states, node)
                self.return_alias |= self.alias_of(node.value)
            self.exit_states.extend(states)
            return set()
        if isinstance(node, ast.Raise):
            # an exceptional exit: tracked mutations before a raise are
            # still mutations the caller may observe
            if node.exc is not None:
                states = self.expr(node.exc, states, node)
            self.exit_states.extend(states)
            return set()
        if isinstance(node, (ast.Break, ast.Continue)):
            # approximated: treated as falling through to after-loop
            return states
        if isinstance(node, ast.Assign):
            states = self.expr(node.value, states, node)
            for t in node.targets:
                states = self.target(t, states, node)
                self.typer.assign(t, node.value)
                self.alias_assign(t, node.value)
            return states
        if isinstance(node, ast.AnnAssign):
            if node.value is not None:
                states = self.expr(node.value, states, node)
                states = self.target(node.target, states, node)
                self.typer.assign(node.target, node.value)
                self.alias_assign(node.target, node.value)
            return states
        if isinstance(node, ast.AugAssign):
            states = self.expr(node.value, states, node)
            # `self._version += 1` is the primitive bump
            t = node.target
            if self.engine.mode == "bump" \
                    and isinstance(t, ast.Attribute) \
                    and isinstance(t.value, ast.Name) \
                    and t.value.id == "self" and t.attr == "_version" \
                    and self.cls in self.project.state_classes:
                return {PathState(s.events, True) for s in states}
            return self.target(t, states, node)
        if isinstance(node, ast.Delete):
            for t in node.targets:
                states = self.target(t, states, node)
            return states
        if isinstance(node, ast.Expr):
            return self.expr(node.value, states, node)
        if isinstance(node, ast.If):
            states = self.expr(node.test, states, node)
            a = self.stmts(node.body, set(states))
            b = self.stmts(node.orelse, set(states))
            return self._merge(a | b)
        if isinstance(node, (ast.For, ast.AsyncFor)):
            states = self.expr(node.iter, states, node)
            self.typer.assign(node.target, node.iter)
            self.alias_assign(node.target, node.iter)
            # body 0, 1 or 2+ times: two unrollings reach the fixpoint
            # of the (events, cleared) lattice for straight-line bodies
            once = self.stmts(node.body, set(states))
            twice = self.stmts(node.body, set(once))
            after = self._merge(states | once | twice)
            return self.stmts(node.orelse, after)
        if isinstance(node, ast.While):
            states = self.expr(node.test, states, node)
            once = self.stmts(node.body, set(states))
            twice = self.stmts(node.body, set(once))
            after = self._merge(states | once | twice)
            return self.stmts(node.orelse, after)
        if isinstance(node, ast.Try):
            body_out = self.stmts(node.body, set(states))
            handler_out = set()
            for h in node.handlers:
                # coarse: a handler may run from any prefix of the body
                handler_out |= self.stmts(
                    h.body, self._merge(set(states) | body_out))
            out = self._merge(body_out | handler_out)
            out = self.stmts(node.orelse, out)
            return self.stmts(node.finalbody, out)
        if isinstance(node, ast.With):
            for item in node.items:
                states = self.expr(item.context_expr, states, node)
            return self.stmts(node.body, states)
        if isinstance(node, (ast.Assert,)):
            return self.expr(node.test, states, node)
        if isinstance(node, (ast.Pass, ast.Import, ast.ImportFrom,
                             ast.Global, ast.Nonlocal)):
            return states
        # anything else: walk child expressions conservatively
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                states = self.expr(child, states, node)
        return states

    # -- mutation targets -----------------------------------------------------

    def target(self, t: ast.AST, states: set, stmt: ast.stmt) -> set:
        """An assignment/del/augassign target: attribute or subscript
        writes into tracked state become events."""
        fields, recv = self.fields_written(t)
        for f in fields:
            states = self.add_event(states, f, stmt.lineno, recv)
        return states

    def fields_written(self, t: ast.AST) -> tuple[set, str]:
        if isinstance(t, ast.Attribute):
            base = t.value
            # self.FIELD = ... / stobj.FIELD = ...
            base_cls = self.typer.of(base)
            if base_cls in self.project.state_classes:
                if t.attr in self.project.tracked:
                    return {t.attr}, _recv_label(base)
                # unknown attrs are the registry-completeness scan's
                # job (mutation.py), not a path-sensitive question
                return set(), ""
            # req.failed = ... — attribute write through an alias
            al = self.alias_of(base)
            if al:
                return set(al), _recv_label(base)
            return set(), ""
        if isinstance(t, ast.Subscript):
            # self.FIELD[k] = ... / alias[k] = ...
            al = self.alias_of(t.value)
            if al:
                return set(al), _recv_label(t.value)
            return set(), ""
        if isinstance(t, (ast.Tuple, ast.List)):
            fields, recv = set(), ""
            for el in t.elts:
                f, r = self.fields_written(el)
                fields |= f
                recv = recv or r
            return fields, recv
        return set(), ""

    def add_event(self, states: set, field: str, line: int,
                  recv: str, note: str = "") -> set:
        ev = Event(field, line, recv, note)
        return {PathState(s.events | {ev}, s.cleared) for s in states}

    # -- expressions ----------------------------------------------------------

    def expr(self, node: ast.expr, states: set, stmt: ast.stmt) -> set:
        """Walk an expression: calls may mutate (mutator methods on
        tracked aliases), clear (touch/bump and always-clearing
        methods) or import a callee's exposed events."""
        for call in [n for n in ast.walk(node)
                     if isinstance(n, ast.Call)]:
            states = self.call(call, states, stmt)
        return states

    def call(self, call: ast.Call, states: set,
             stmt: ast.stmt) -> set:
        f = call.func
        if not isinstance(f, ast.Attribute):
            return states
        recv, name = f.value, f.attr
        recv_cls = self.typer.of(recv)
        line = getattr(call, "lineno", stmt.lineno)
        # 1. the clearing primitives and analyzed-method calls
        if recv_cls in self.project.state_classes:
            s = self.engine.summary(recv_cls, name)
            for ev in s.exposed:
                states = self.add_event(
                    states, ev.field, line, _recv_label(recv),
                    note=f"via {recv_cls}.{name} (line {ev.line})")
            if s.always_clears:
                return {PathState(st.events, True) for st in states}
            if name in self.project.mutators:
                al = self.alias_of(recv)
                for fld in al:
                    states = self.add_event(states, fld, line,
                                            _recv_label(recv))
            return states
        # 2. checkpoint-manager mutators piggyback on state versions
        if recv_cls == "CheckpointManager" \
                and name in self.project.ckpt_mutators:
            return self.add_event(
                states, "ckpt(shared)", line, _recv_label(recv),
                note="checkpoint records are versioned by the owning "
                     "shell's _version (checkpoint.py CKPT_MUTATORS)")
        # 3. mutator methods on aliases of tracked fields
        if name in self.project.mutators:
            al = self.alias_of(recv)
            for fld in al:
                states = self.add_event(states, fld, line,
                                        _recv_label(recv))
        # 4. calls into other analyzed classes (e.g. fixture helpers)
        if recv_cls is not None \
                and recv_cls not in ("CheckpointManager",):
            s = self.engine.summary(recv_cls, name)
            for ev in s.exposed:
                states = self.add_event(
                    states, ev.field, line, _recv_label(recv),
                    note=f"via {recv_cls}.{name} (line {ev.line})")
            if s.always_clears:
                states = {PathState(st.events, True) for st in states}
        return states

    # -- aliases --------------------------------------------------------------

    def alias_of(self, expr: ast.AST) -> frozenset:
        """Tracked fields `expr` may refer into (coarse, transitive)."""
        if isinstance(expr, ast.Name):
            if expr.id == "self":
                return frozenset()
            return self.aliases.get(expr.id, frozenset())
        if isinstance(expr, ast.Attribute):
            base_cls = self.typer.of(expr.value)
            if base_cls in self.project.state_classes \
                    and expr.attr in self.project.tracked:
                return frozenset({expr.attr})
            return self.alias_of(expr.value)
        if isinstance(expr, ast.Subscript):
            return self.alias_of(expr.value)
        if isinstance(expr, ast.Call):
            f = expr.func
            if isinstance(f, ast.Attribute):
                recv_cls = self.typer.of(f.value)
                if recv_cls in self.project.state_classes:
                    return self.engine.summary(
                        recv_cls, f.attr).returns_alias
                return self.alias_of(f.value)
            if isinstance(f, ast.Name) and f.id in (
                    "sorted", "list", "tuple", "reversed", "iter",
                    "next", "min", "max"):
                out = frozenset()
                for a in expr.args:
                    out |= self.alias_of(a)
                return out
            return frozenset()
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            out = frozenset()
            for el in expr.elts:
                out |= self.alias_of(el)
            return out
        if isinstance(expr, (ast.IfExp,)):
            return self.alias_of(expr.body) | self.alias_of(expr.orelse)
        if isinstance(expr, ast.BoolOp):
            out = frozenset()
            for v in expr.values:
                out |= self.alias_of(v)
            return out
        if isinstance(expr, ast.Starred):
            return self.alias_of(expr.value)
        if isinstance(expr, (ast.ListComp, ast.SetComp,
                             ast.GeneratorExp)):
            out = frozenset()
            for gen in expr.generators:
                out |= self.alias_of(gen.iter)
            return out
        return frozenset()

    def alias_assign(self, target: ast.AST, value: ast.AST) -> None:
        al = self.alias_of(value)
        if isinstance(target, ast.Name):
            if al:
                self.aliases[target.id] = al
            else:
                self.aliases.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            vals = value.elts if isinstance(
                value, (ast.Tuple, ast.List)) else None
            for i, tgt in enumerate(target.elts):
                self.alias_assign(
                    tgt, vals[i] if vals and i < len(vals) else value)
