"""Repo-layout knowledge for schedlint (the part that is config, not
contract).

The *contracts* — which fields are tracked, which memo caches exist and
what they key on — live in the checked sources themselves as literal
constants (`scheduler.TRACKED_FIELDS`, `fabric.MEMO_CONTRACTS`, ...),
next to the code they constrain.  This module holds what does not
belong there: the coarse receiver-type map the AST engine needs to
resolve `vst.steal_pending(...)` to a `SchedulerState` summary, the
classification of every known attribute into versioned-state tokens,
and the per-module determinism allowlist.  Fixture files under
tests/fixtures/lint/ are self-contained and override all of this via
in-file `SCHEDLINT_*` declarations.
"""
from __future__ import annotations

# -- class layout -------------------------------------------------------------

STATE_CLASS = "SchedulerState"

# fallbacks when no TRACKED_FIELDS declaration is in the project (the
# real run always extracts the declaration from scheduler.py; an empty
# fallback keeps fixture projects explicit)
TRACKED_FALLBACK: tuple = ()
MUTATORS_FALLBACK: tuple = (
    "append", "appendleft", "extend", "insert", "pop", "popleft",
    "remove", "discard", "add", "update", "clear", "setdefault",
)

# receiver-class inference: bare local names conventionally holding a
# SchedulerState, and (owner-class, attr) -> class of the attribute
# (for containers: the *element* class, since the typer collapses
# subscripts/.get()/.values() onto the container's mapping)
TYPE_HINTS: dict = {
    "st": "SchedulerState",
    "vst": "SchedulerState",
    "tst": "SchedulerState",
    "state": "SchedulerState",
    ("Fabric", "states"): "SchedulerState",
    ("Fabric", "cost"): "CostModel",
    ("Fabric", "ckpt"): "CheckpointManager",
    ("Fabric", "arrivals"): "ArrivalEstimator",
    ("Fabric", "slo"): "AdmissionController",
    ("Fabric", "jobs"): "FabricJob",
    ("Fabric", "_admission"): "FabricJob",
    ("SchedulerState", "cost"): "CostModel",
    ("SchedulerState", "ckpt"): "CheckpointManager",
    ("SchedulerState", "arrivals"): "ArrivalEstimator",
    ("SchedulerState", "alloc"): "BuddyAllocator",
    ("SchedulerState", "queues"): "Request",
    ("SchedulerState", "requests"): "Request",
    ("SchedulerState", "active"): "Assignment",
    ("ArrivalEstimator", "_classes"): "ClassStats",
    ("AdmissionController", "fabric"): "Fabric",
    ("Fabric", "network"): "FabricNetwork",
    ("FabricNetwork", "_links"): "Link",
    ("FabricNetwork", "_routes"): "Link",
    ("FabricNetwork", "_active"): "Transfer",
    ("FabricNetwork", "_pending"): "Transfer",
    ("Transfer", "route"): "Link",
}

# -- versioned-state tokens (memo checker) ------------------------------------
#
# Every attribute a memoized computation may read is classified into a
# token; a memo cache's declared key must cover every token its
# computation reaches (analysis/memo.py).  Tokens:
#
#   state    — covered by SchedulerState._version (TRACKED_FIELDS plus
#              everything the mutation checker forces bumps for)
#   cost     — CostModel.version
#   arrivals — ArrivalEstimator._version
#   reserve  — the per-event reservation sample (_reserve_last), taken
#              for every shell on every fabric event (sample_reserve)
#   now      — the event clock (a `now` parameter or `_now` read)
#   tenant_service — the fabric-shared service map; moves without any
#              version, so no memo key can cover it: any read inside a
#              cached region is a finding by construction
#   net      — FabricNetwork.version: link occupancy (busy_until,
#              inflight) moved by reserve/advance; constant (version 0)
#              on the degenerate uniform topology
#
# None means "safe": static configuration, admission-time constants,
# or self-invalidating caches.
VERSIONED: dict = {
    # SchedulerState: tracked fields resolve via TRACKED_FIELDS; the
    # rest of its surface:
    ("SchedulerState", "_version"): "state",
    ("SchedulerState", "_reserve_last"): "reserve",
    ("SchedulerState", "_reserve_now"): "reserve",
    ("SchedulerState", "reserve_history"): "reserve",
    ("SchedulerState", "_now"): "now",
    ("SchedulerState", "_tenant_last_ms"): "tenant_service",
    ("SchedulerState", "_save_ms_pending"): "state",
    ("SchedulerState", "n_preemptions"): "state",
    ("SchedulerState", "_preempted"): "state",
    ("SchedulerState", "speed"): None,
    ("SchedulerState", "policy"): None,
    ("SchedulerState", "registry"): None,
    ("SchedulerState", "name"): None,
    ("SchedulerState", "ckpt_capable"): None,
    ("SchedulerState", "_observe_arrivals"): None,
    ("SchedulerState", "transfer_of"): None,
    ("SchedulerState", "on_change"): None,
    # observability callback (repro.obs): fired on reserve changes,
    # never read by scheduling decisions
    ("SchedulerState", "on_reserve"): None,
    ("SchedulerState", "RESERVE_HYSTERESIS"): None,
    ("CostModel", "_est"): "cost",
    ("CostModel", "version"): "cost",
    ("CostModel", "registry"): None,
    ("CostModel", "alpha"): None,
    ("ArrivalEstimator", "_classes"): "arrivals",
    ("ArrivalEstimator", "_version"): "arrivals",
    ("ArrivalEstimator", "alpha"): None,
    # the demand memo is self-invalidating on (now, _version); reads of
    # the cache structure itself are safe
    ("ArrivalEstimator", "_demand"): None,
    ("ArrivalEstimator", "_demand_at"): None,
    ("ClassStats", "last_t"): "arrivals",
    ("ClassStats", "ia_ms"): "arrivals",
    ("ClassStats", "service_ms"): "arrivals",
    ("ClassStats", "footprint"): "arrivals",
    ("ClassStats", "n"): "arrivals",
    # checkpoint records are versioned by the owning shell's _version:
    # every CKPT_MUTATORS call site is forced onto a bumped path by the
    # mutation checker, so "state" in a memo key covers them
    ("CheckpointManager", "_recs"): "state",
    ("CheckpointManager", "_rid_progress"): "state",
    ("CheckpointManager", "registry"): None,
    ("CheckpointManager", "policy"): None,
    ("CheckpointManager", "stats"): None,     # reporting counters
    ("ChunkCheckpoint", "remaining"): "state",
    ("ChunkCheckpoint", "rid"): "state",
    ("ChunkCheckpoint", "chunk"): "state",
    ("ChunkCheckpoint", "shell"): "state",
    ("ChunkCheckpoint", "context_kb"): "state",
    ("BuddyAllocator", "_mask"): "state",
    ("BuddyAllocator", "busy"): "state",
    ("BuddyAllocator", "n"): None,            # fixed at construction
    # largest_free memo: self-invalidating on _mask equality
    ("BuddyAllocator", "_lf_mask"): None,
    ("BuddyAllocator", "_lf_best"): None,
    # fabric surface reachable from the memoized computations
    ("Fabric", "states"): None,               # membership fixed at init
    ("Fabric", "policy"): None,
    ("Fabric", "registry"): None,
    ("Fabric", "speeds"): None,
    ("Fabric", "ckpt_capable"): None,
    ("Fabric", "_transfer"): None,            # static topology costs
    ("Fabric", "full_reschedule"): None,
    # _subs entries are created/removed only alongside a touch of the
    # owning shell (submit in _dispatch/_steal_from, abort): covered by
    # the victim/thief versions in any key containing "state"
    ("Fabric", "_subs"): "state",
    ("Fabric", "_backlog_cache"): None,       # the memo itself
    ("Fabric", "_steal_fail"): None,          # the memo itself
    # stats counters are bumped on the steal *success* path, which the
    # failure fingerprint never caches; plain reporting either way
    ("Fabric", "stats"): None,
    # executor drain queues / per-sub bookkeeping: written on success
    # paths only, never read by a cached computation's decision
    ("Fabric", "_moved"): None,
    ("Fabric", "_sub_transfer"): None,
    ("Fabric", "_now"): "now",
    # flight recorder head (repro.obs): write-only telemetry from the
    # fabric's point of view — hooks observe decisions, never make them
    ("Fabric", "obs"): None,
    # FabricJob fields read on steal/dispatch paths are admission-time
    # constants; the mutable ones (done, subs) are only touched on
    # success paths that also touch the involved shells
    ("FabricJob", "tenant"): None,
    ("FabricJob", "module"): None,
    ("FabricJob", "n_chunks"): None,
    ("FabricJob", "priority"): None,
    ("FabricJob", "deadline_ms"): None,
    ("FabricJob", "deadline_at"): None,
    ("FabricJob", "t_submit"): None,
    ("FabricJob", "payloads"): None,
    ("FabricJob", "gid"): None,
    ("FabricJob", "subs"): "state",
    ("FabricJob", "done"): "state",
    ("FabricJob", "failed"): "state",
    # link-level interconnect (core/network.py): occupancy is "net"
    # versioned state; topology/link parameters are fixed at build
    ("FabricNetwork", "version"): "net",
    ("FabricNetwork", "_active"): "net",
    ("FabricNetwork", "_pending"): "net",
    ("FabricNetwork", "_mode"): None,
    ("FabricNetwork", "_default"): None,
    ("FabricNetwork", "_pairs"): None,
    ("FabricNetwork", "_links"): None,         # membership fixed at build
    ("FabricNetwork", "_routes"): None,
    ("FabricNetwork", "_ports"): None,
    ("FabricNetwork", "active"): None,
    ("FabricNetwork", "has_ingress"): None,
    ("FabricNetwork", "inflight"): "net",
    ("Link", "busy_until"): "net",
    ("Link", "inflight"): "net",
    ("Link", "latency_ms"): None,
    ("Link", "bw_ms"): None,
    ("Link", "buffer"): None,
    ("Link", "src"): None,
    ("Link", "dst"): None,
    ("Link", "name"): None,
    ("Link", "busy_ms"): None,                 # reporting stats
    ("Link", "transfers"): None,
    ("Link", "max_queue"): None,
    ("Transfer", "src"): None,
    ("Transfer", "dst"): None,
    ("Transfer", "payload"): None,
    ("Transfer", "route"): None,
    ("Transfer", "t_start"): "net",
    ("Transfer", "t_done"): "net",
    ("Transfer", "wait_ms"): "net",
    ("Transfer", "total_ms"): "net",
}

# attribute-name fallback for receivers the typer cannot resolve (deque
# elements held in odd locals, dataclass results): Request/Assignment
# surfaces are scheduling state by definition
REQUEST_ATTRS = frozenset({
    "rid", "tenant", "module", "n_chunks", "_chunks", "done", "failed",
    "t_submit", "t_finish", "t_last_served", "priority", "deadline_ms",
    "preemptions", "pending", "outstanding", "complete", "deadline_at",
    "aid", "chunk", "footprint", "rng", "reconfigure", "eff", "t_start",
    "frac", "restore_ms", "save_ms", "start", "size", "slots",
    "remaining",
})

# -- determinism --------------------------------------------------------------

# modules on the simulator path: one nondeterministic read anywhere in
# these breaks golden-trace byte-identity and incremental/full
# equivalence
SIM_MODULES = (
    "scheduler", "fabric", "simulator", "arrivals", "checkpoint",
    "allocator", "slo", "network",
)

# intentional exceptions outside the sim path, (module, rule) -> why.
# Sim-path modules get no entries here on purpose: an exception there
# must sit on the offending line as a pragma, visible in review.
DETERMINISM_ALLOWLIST: dict = {
    ("daemon", "wall-clock"):
        "the daemon IS the wall-clock binding: it feeds "
        "perf_counter-derived times into the same fabric API the "
        "simulator drives with virtual time",
    ("module", "wall-clock"):
        "kernel benchmarking measures real device time by definition "
        "(block_until_ready around the pallas call)",
    ("module", "randomness"):
        "weight init uses jax.random with a fixed seed per module; "
        "numerics never feed back into scheduling decisions",
    ("zoo", "randomness"):
        "module zoo builds test inputs with seeded jax.random keys",
    ("export", "wall-clock"):
        "the Chrome-trace exporter (repro.obs.export) stamps the "
        "capture time into the artifact's otherData for provenance; "
        "it renders already-recorded events and nothing flows back "
        "into scheduling (trace/recorder stay strict sim modules)",
}

# safe attribute reads not worth a VERSIONED entry (dunder/bookkeeping)
SAFE_ATTRS: dict = {}
