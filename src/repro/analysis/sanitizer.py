"""Runtime sanitizer for the scheduler's mutation-tracking contract.

The static mutation checker (analysis/mutation.py) proves that every
*lexical* mutation path through `SchedulerState` bumps the version; this
module catches what the AST cannot see — dynamic mutations through
aliases (`st.requests[rid]._chunks.append(...)` held in a local across
calls), direct `busy`-set pokes that bypass the allocator chokepoint,
or any future executor reaching into scheduling state without firing
`_touch`.  Mechanism:

  - with `REPRO_SANITIZE=1` (or `SANITIZE` toggled at runtime by a
    test), every `SchedulerState` keeps a shadow snapshot
    `(version, hash-of-tracked-fields)` taken at the end of each
    scheduling pass;
  - at the start of the next pass — and, on a fabric, for *every*
    shell on every `Fabric.schedule` event, the clean (elided) shells
    included, since those are exactly the ones a silent mutation would
    corrupt — the shadow is recomputed and compared: a hash change
    with no version bump in between raises `SanitizerError`.

The hash covers exactly the fields the dirty-shell invariant depends on
(`scheduler.TRACKED_FIELDS`); fabric-shared structures (cost model,
arrival estimator, checkpoint manager, tenant service map) carry their
own versions or per-event sampling and are deliberately excluded — a
legitimate mutation by a sibling shell must not trip a clean shell's
check.  All hashing is deterministic (sorted sets, `repr` floats,
`zlib.crc32`), so a sanitized run is byte-identical to an unsanitized
one apart from the checks themselves — the equivalence property tests
run under `REPRO_SANITIZE=1` in CI and double as sanitizer coverage.
"""
from __future__ import annotations

import os
import zlib

# Runtime toggle: environment opt-in, or set `sanitizer.SANITIZE = True`
# from a test.  Read once here so the scheduler's per-call guard is one
# global load, never an environment probe on the hot path.
SANITIZE = os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


class SanitizerError(AssertionError):
    """Tracked scheduling state changed without a version bump."""


def _req_key(req) -> tuple:
    return (req.rid, req.tenant, req.module, req.n_chunks,
            tuple(req._chunks), req.done, req.failed,
            repr(req.t_submit), repr(req.t_finish),
            repr(req.t_last_served), req.priority,
            repr(req.deadline_ms), req.preemptions)


def shadow_hash(st) -> int:
    """Deterministic digest of a `SchedulerState`'s tracked fields.

    Everything order-dependent is canonicalised (dicts by sorted key,
    sets sorted) and floats go through `repr` (exact round-trip), so
    equal scheduling states hash equal across runs and platforms.
    """
    parts: list = ["q"]
    for tenant in sorted(st.queues):
        parts.append(tenant)
        parts.extend(_req_key(r) for r in st.queues[tenant])
    parts.append("r")
    for rid in sorted(st.requests):
        parts.append(_req_key(st.requests[rid]))
    parts.append("a")
    for aid in sorted(st.active):
        a = st.active[aid]
        parts.append((a.rid, a.chunk, a.module, a.footprint,
                      a.rng.start, a.rng.size, a.reconfigure, a.eff,
                      repr(a.t_start), repr(a.frac), repr(a.restore_ms),
                      repr(a.save_ms)))
    parts.append(("res", tuple(sorted(st.resident.items()))))
    parts.append(("alloc", st.alloc.n, st.alloc._mask,
                  tuple(sorted(st.alloc.busy))))
    parts.append(("n", st._pending_n, st._serve_seq,
                  tuple(sorted(st._served_at.items()))))
    return zlib.crc32(repr(parts).encode())


def check(st) -> None:
    """Raise `SanitizerError` if `st`'s tracked fields changed since the
    last `rearm` without a version bump; then re-arm the snapshot."""
    snap = getattr(st, "_shadow", None)
    h = shadow_hash(st)
    if snap is not None and snap[1] != h and snap[0] == st._version:
        raise SanitizerError(
            f"SchedulerState {st.name or '<anon>'}: tracked fields "
            f"(scheduler.TRACKED_FIELDS) mutated with no version bump "
            f"since the last scheduling pass (version still "
            f"{st._version}).  The incremental fabric would keep "
            f"treating this shell as a scheduling fixpoint and never "
            f"reschedule it — a silent divergence from "
            f"full_reschedule.  Route the mutation through a "
            f"SchedulerState method, or fire st._touch() after it.")
    st._shadow = (st._version, h)


def rearm(st) -> None:
    """Snapshot `st` after a pass legitimately mutated it."""
    st._shadow = (st._version, shadow_hash(st))
