"""Shared helpers for per-architecture configs."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.api import ModelConfig, MoEConfig, SSMConfig, ShapeCell


def apply_cell_policy(cfg: ModelConfig, cell: ShapeCell,
                      production: bool = True) -> ModelConfig:
    """Specialise a config for a shape cell (training vs serving policies)."""
    updates: dict = {}
    if cell.seq_len > 2048 and cell.kind in ("train", "prefill"):
        # q-block-chunked attention: never materialise [S, S] scores
        updates["attn_chunk"] = 1024
    if cell.kind == "train":
        # remat="full": save only layer boundaries (which are
        # sequence-sharded over the model axis -- Megatron-style SP);
        # "dots" would persist every projection output and OOMs at
        # global_batch=256 x 4k.
        updates.update(remat="full", loss_chunk=1024 if cell.seq_len >= 4096
                       else 0, param_dtype=jnp.float32)
        if cfg.moe is not None and production:
            updates["moe"] = dataclasses.replace(
                cfg.moe, impl="ep", fsdp_experts=True)
    else:
        updates.update(param_dtype=jnp.bfloat16, remat="none", loss_chunk=0)
        if cfg.moe is not None and production:
            updates["moe"] = dataclasses.replace(
                cfg.moe, impl="ep", fsdp_experts=False)
    if cfg.family == "encdec":
        updates["max_pos"] = max(cfg.max_pos, cell.seq_len + 1)
    return dataclasses.replace(cfg, **updates)
