"""mamba2-780m [ssm] — SSD (state-space duality).  [arXiv:2405.21060; unverified]

48 blocks, d_model=1536 (d_inner=3072, headdim=64 => 48 SSD heads),
d_state=128, attention-free.
"""
from repro.models.api import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2, chunk=128))

REDUCED = ModelConfig(
    name="mamba2-780m-reduced", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=256,
    ssm=SSMConfig(d_state=16, headdim=16, expand=2, chunk=16))
