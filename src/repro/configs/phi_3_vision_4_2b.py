"""phi-3-vision-4.2b [vlm] — phi3-mini + CLIP (frontend stubbed).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

Backbone only: input_specs() supplies pre-projected patch embeddings
(576 patches at d_model) occupying the first sequence positions.
MHA: kv=32, head_dim=96.
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064, rope_theta=10000.0, n_patches=576)

REDUCED = ModelConfig(
    name="phi-3-vision-4.2b-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, rope_theta=10000.0, n_patches=4)
