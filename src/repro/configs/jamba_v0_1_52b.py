"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

32 layers = 4 scanned super-blocks of 8 sub-layers (attn at index 4, mamba
elsewhere; MoE FFN on odd sub-layers).  Jamba v0.1 uses Mamba-1 internally;
we substitute our TPU-native Mamba2/SSD block with d_state=16 (see DESIGN.md
hardware-adaptation notes).  d_inner=8192, headdim=64 => 128 SSD heads.
"""
from repro.models.api import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536, attn_every=8,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336, every=2),
    ssm=SSMConfig(d_state=16, headdim=64, expand=2, chunk=128))

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-reduced", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, attn_every=4,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=128, every=2),
    ssm=SSMConfig(d_state=16, headdim=16, expand=2, chunk=16))
