"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]

Backbone only: 32 encoder + 32 decoder layers, d_model=1280, 20 heads
(MHA: kv=20), GELU MLP, LayerNorm, attention biases, learned decoder
positions, sinusoidal encoder positions.  input_specs() supplies
precomputed frame embeddings (1500 frames) in place of the conv frontend.
"""
from repro.models.api import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    head_dim=64, d_ff=5120, vocab=51866, enc_seq=1536,
    mlp_kind="gelu", norm_kind="layer", attn_bias=True, max_pos=4096)
# enc_seq: whisper's conv frontend yields 1500 frames; the stub pads to 1536
# so the cross-attention cache sequence axis shards evenly (see DESIGN.md).

REDUCED = ModelConfig(
    name="whisper-large-v3-reduced", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, enc_seq=8,
    mlp_kind="gelu", norm_kind="layer", attn_bias=True, max_pos=64)
