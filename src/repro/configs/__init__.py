"""Architecture config registry: one module per assigned architecture.

Usage:
    from repro import configs
    cfg = configs.get("qwen3-14b")           # full (assignment) config
    cfg = configs.get("qwen3-14b", reduced=True)   # smoke-test config
    configs.ARCH_IDS                          # all ids
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "granite-3-8b",
    "yi-9b",
    "qwen3-14b",
    "llama3.2-3b",
    "whisper-large-v3",
    "qwen3-moe-30b-a3b",
    "phi3.5-moe-42b-a6.6b",
    "mamba2-780m",
    "phi-3-vision-4.2b",
    "jamba-v0.1-52b",
]

_MODULES = {
    "granite-3-8b": "granite_3_8b",
    "yi-9b": "yi_9b",
    "qwen3-14b": "qwen3_14b",
    "llama3.2-3b": "llama3_2_3b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "mamba2-780m": "mamba2_780m",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get(arch_id: str, reduced: bool = False):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.REDUCED if reduced else mod.CONFIG
