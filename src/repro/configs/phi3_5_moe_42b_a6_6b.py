"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.api import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab=32064, rope_theta=10000.0,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400))

REDUCED = ModelConfig(
    name="phi3.5-moe-42b-a6.6b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=0, vocab=256, rope_theta=10000.0,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff=32))
