"""Decoder stacks, losses, prefill and decode steps for every family.

All functions are pure and jit-able; `mesh`/`batch_axes` are static context
used only by the expert-parallel MoE path (None => dense MoE oracle).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import api, layers, mamba as mamba_mod, moe as moe_mod
from repro.models.api import ModelConfig
from repro.sharding import partition


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _norm(sub, prefix, x, cfg: ModelConfig):
    if cfg.norm_kind == "rms":
        return layers.rms_norm(x, sub[f"{prefix}_w"])
    return layers.layer_norm(x, sub[f"{prefix}_w"], sub[f"{prefix}_b"])


def moe_spec(cfg: ModelConfig) -> moe_mod.MoESpec:
    m = cfg.moe
    return moe_mod.MoESpec(
        n_experts=m.n_experts, top_k=m.top_k, d_ff=m.d_ff,
        capacity_factor=m.capacity_factor, impl=m.impl,
        fsdp_experts=m.fsdp_experts)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / dim))
    pe = jnp.zeros((seq, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(cfg.remat)


# ---------------------------------------------------------------------------
# one sub-layer (mixer + optional cross-attn + ffn)
# ---------------------------------------------------------------------------


def _sublayer(sub, cfg: ModelConfig, plan_item, h, positions, *,
              cache=None, cache_pos=None, cross_kv=None, enc_out=None,
              mesh=None, batch_axes=("data",), attn_causal=True):
    """Returns (h, new_cache, aux)."""
    mixer, ffn = plan_item
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    if mixer == "attn":
        spec = cfg.attn_spec
        if not attn_causal:
            spec = dataclasses.replace(spec, causal=False)
        kv = None if cache is None else {"k": cache["k"], "v": cache["v"]}
        y, new_kv = layers.attention(
            sub["attn"], _norm(sub, "ln1", h, cfg), spec, positions,
            attn_impl=cfg.attn_impl, kv_cache=kv, cache_pos=cache_pos,
            mesh=mesh)
        h = h + y
        if new_kv is not None:
            new_cache.update(new_kv)
    else:
        state = None
        if cache is not None:
            state = (cache["ssm"], cache["conv_x"], cache["conv_bc"])
        y, new_state = mamba_mod.mamba_block(
            sub["mamba"], _norm(sub, "ln1", h, cfg), cfg.mamba_spec,
            state=state)
        h = h + y
        new_cache.update({"ssm": new_state[0], "conv_x": new_state[1],
                          "conv_bc": new_state[2]})
    if "xattn" in sub:
        if cross_kv is None:
            assert enc_out is not None
            ck = layers.cross_kv_from_encoder(sub["xattn"], enc_out,
                                              cfg.attn_spec)
        else:
            ck = (cross_kv["xk"], cross_kv["xv"])
        y, _ = layers.attention(
            sub["xattn"], _norm(sub, "lnx", h, cfg), cfg.attn_spec,
            positions, attn_impl="xla", cross_kv=ck, mesh=mesh)
        h = h + y
        if cache is not None and cross_kv is None:
            new_cache.update({"xk": ck[0], "xv": ck[1]})
        elif cross_kv is not None:
            new_cache.update({"xk": cross_kv["xk"], "xv": cross_kv["xv"]})
    if ffn == "dense":
        h = h + layers.mlp(sub["mlp"], _norm(sub, "ln2", h, cfg),
                           cfg.mlp_kind)
    elif ffn == "moe":
        y, aux = moe_mod.moe_ffn(sub["moe"], _norm(sub, "ln2", h, cfg),
                                 moe_spec(cfg), mesh, batch_axes)
        h = h + y
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# scanned stack
# ---------------------------------------------------------------------------


def run_stack(blocks, cfg: ModelConfig, h, positions, *, plan=None,
              cache=None, cache_pos=None, enc_out=None, decode_cross=False,
              mesh=None, batch_axes=("data",), attn_causal=True):
    """Scan the (stacked) block params over h.

    blocks: pytree whose leaves have a leading `groups` axis.
    cache: matching pytree (leading groups axis) or None.
    Returns (h, new_cache, aux_sum).
    """
    if plan is None:
        _, plan = cfg.layer_plan()

    def body(carry, xs):
        hh, aux_acc = carry
        group, cache_g = xs
        # sequence-parallel residual stream: the scan carry (the only
        # activation persisted per layer under remat="full") is sharded
        # over the model axis along seq when the rules say so
        hh = partition.constrain(hh, ("batch", "seq", "embed_act"))
        new_cache_g = {}
        for i, item in enumerate(plan):
            sub = group[f"sub{i}"]
            sub_cache = None if cache_g is None else cache_g[f"sub{i}"]
            cross_kv = None
            if decode_cross and sub_cache is not None and "xk" in sub_cache:
                cross_kv = {"xk": sub_cache["xk"], "xv": sub_cache["xv"]}
            hh, nc, aux = _sublayer(
                sub, cfg, item, hh, positions, cache=sub_cache,
                cache_pos=cache_pos, cross_kv=cross_kv, enc_out=enc_out,
                mesh=mesh, batch_axes=batch_axes, attn_causal=attn_causal)
            new_cache_g[f"sub{i}"] = nc
            aux_acc = aux_acc + aux
        return (hh, aux_acc), new_cache_g

    body = _remat(body, cfg)
    zero = jnp.zeros((), jnp.float32)
    if not cfg.scan_layers:
        # unrolled (used by the dry-run cost-extrapolation compiles; every
        # layer appears in the HLO so cost_analysis counts it exactly)
        n_groups = jax.tree.leaves(blocks)[0].shape[0]
        carry = (h, zero)
        caches = []
        for i in range(n_groups):
            group_i = jax.tree.map(lambda x: x[i], blocks)
            cache_i = (None if cache is None
                       else jax.tree.map(lambda x: x[i], cache))
            carry, nc = body(carry, (group_i, cache_i))
            caches.append(nc)
        h, aux = carry
        if cache is None:
            return h, None, aux
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        return h, new_cache, aux
    if cache is None:
        # lax.scan requires xs pytrees to agree; use params-only xs
        def body_nocache(carry, group):
            return body(carry, (group, None))
        (h, aux), _ = jax.lax.scan(body_nocache, (h, zero), blocks)
        return h, None, aux
    (h, aux), new_cache = jax.lax.scan(body, (h, zero), (blocks, cache))
    return h, new_cache, aux


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg: ModelConfig, tokens):
    emb = params["embed"]["tok"]
    return emb[tokens].astype(cfg.compute_dtype)


def unembed(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].astype(cfg.compute_dtype)   # [Vp, D]
        logits = jnp.einsum("bsd,vd->bsv", h, w,
                            preferred_element_type=jnp.float32)
    else:
        w = params["lm_head"].astype(cfg.compute_dtype)        # [D, Vp]
        logits = jnp.einsum("bsd,dv->bsv", h, w,
                            preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab:
        # mask Megatron-style vocab padding slots
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


# ---------------------------------------------------------------------------
# full forwards
# ---------------------------------------------------------------------------


def _encode(params, cfg: ModelConfig, frames, mesh, batch_axes):
    """Whisper encoder over stub frame embeddings [B, Se, D]."""
    se = frames.shape[1]
    h = frames.astype(cfg.compute_dtype)
    h = h + sinusoidal_positions(se, cfg.d_model).astype(cfg.compute_dtype)
    positions = jnp.arange(se)
    h, _, _ = run_stack(params["enc_blocks"], cfg, h, positions,
                        plan=[("attn", "dense")], mesh=mesh,
                        batch_axes=batch_axes, attn_causal=False)
    return _norm(params["enc_final"], "lnf", h, cfg)


def forward(params, cfg: ModelConfig, batch, *, mesh=None,
            batch_axes=("data",)):
    """Training/teacher-forcing forward. batch: dict with `tokens` [B,S]
    (+ `frames` for encdec, `patches` for vlm). Returns (h_final, aux)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    h = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(s)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, batch["frames"], mesh, batch_axes)
        h = h + params["dec_pos"][:s].astype(cfg.compute_dtype)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.compute_dtype)
        h = jnp.concatenate([patches, h[:, patches.shape[1]:]], axis=1)
    h, _, aux = run_stack(params["blocks"], cfg, h, positions,
                          enc_out=enc_out, mesh=mesh, batch_axes=batch_axes)
    h = _norm(params["final"], "lnf", h, cfg)
    return h, aux


def _gold_logit(logits, targets):
    """logits[..., targets] via a masked sum, NOT take_along_axis: a gather
    along the vocab-sharded axis makes GSPMD all-gather the whole logits
    tensor (measured: ~4 GB/step of AG+scatter-AR on yi-9b); the masked sum
    reduces shard-locally and psums a scalar per position."""
    vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    return jnp.sum(jnp.where(vpos == targets[..., None], logits, 0.0),
                   axis=-1)


def loss_from_hidden(params, cfg: ModelConfig, h, tokens, aux):
    """Next-token CE, optionally chunked over the sequence to avoid
    materialising [B, S, V] logits."""
    b, s = tokens.shape
    targets = tokens[:, 1:]
    hh = h[:, :-1]
    n = b * (s - 1)
    if cfg.loss_chunk and (s - 1) % cfg.loss_chunk == 0:
        nc = (s - 1) // cfg.loss_chunk
        hh = hh.reshape(b, nc, cfg.loss_chunk, cfg.d_model)
        tt = targets.reshape(b, nc, cfg.loss_chunk)

        @jax.checkpoint  # don't keep per-chunk logits as scan residuals
        def chunk_loss(carry, xs):
            hc, tc = xs
            logits = unembed(params, cfg, hc)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = _gold_logit(logits, tc)
            return carry + jnp.sum(lse - gold), None

        total, _ = jax.lax.scan(
            chunk_loss, jnp.zeros((), jnp.float32),
            (jnp.moveaxis(hh, 1, 0), jnp.moveaxis(tt, 1, 0)))
        loss = total / n
    else:
        logits = unembed(params, cfg, hh)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = _gold_logit(logits, targets)
        loss = jnp.sum(lse - gold) / n
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    return loss + aux_w * aux


def build_loss_fn(cfg: ModelConfig, mesh=None, batch_axes=("data",)):
    def loss_fn(params, batch):
        h, aux = forward(params, cfg, batch, mesh=mesh,
                         batch_axes=batch_axes)
        return loss_from_hidden(params, cfg, h, batch["tokens"], aux)
    return loss_fn


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStructs for the decode cache (matches run_stack layout)."""
    shapes = _cache_shapes(cfg, batch, max_len)
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]), shapes,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def _cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    n_groups, plan = cfg.layer_plan()
    ms = cfg.mamba_spec if cfg.family in ("ssm", "hybrid") else None
    group = {}
    for i, (mixer, ffn) in enumerate(plan):
        sub = {}
        if mixer == "attn":
            kv_shape = (n_groups, batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim)
            sub["k"] = (kv_shape, cfg.kv_dtype)
            sub["v"] = (kv_shape, cfg.kv_dtype)
        else:
            sub["ssm"] = ((n_groups, batch, ms.n_heads, ms.headdim,
                           ms.d_state), jnp.float32)
            sub["conv_x"] = ((n_groups, batch, ms.conv_kernel - 1,
                              ms.d_inner), cfg.kv_dtype)
            sub["conv_bc"] = ((n_groups, batch, ms.conv_kernel - 1,
                               ms.bc_dim), cfg.kv_dtype)
        if cfg.family == "encdec":
            x_shape = (n_groups, batch, cfg.enc_seq, cfg.n_kv_heads,
                       cfg.head_dim)
            sub["xk"] = (x_shape, cfg.kv_dtype)
            sub["xv"] = (x_shape, cfg.kv_dtype)
        group[f"sub{i}"] = sub
    return group


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    shapes = _cache_shapes(cfg, batch, max_len)
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], sd[1]), shapes,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))


def cache_axis_specs(cfg: ModelConfig, enc_len: int = 0):
    """Logical axes pytree matching the cache."""
    n_groups, plan = cfg.layer_plan()
    group = {}
    for i, (mixer, ffn) in enumerate(plan):
        sub = {}
        if mixer == "attn":
            ax = ("layers", "batch", "seq_kv", "kv_heads_kv", None)
            sub["k"] = ax
            sub["v"] = ax
        else:
            sub["ssm"] = ("layers", "batch", "heads_ssm", None, None)
            sub["conv_x"] = ("layers", "batch", None, "inner")
            sub["conv_bc"] = ("layers", "batch", None, None)
        if cfg.family == "encdec":
            ax = ("layers", "batch", None, "kv_heads_kv", None)
            sub["xk"] = ax
            sub["xv"] = ax
        group[f"sub{i}"] = sub
    return group


def build_prefill_fn(cfg: ModelConfig, max_len: int, mesh=None,
                     batch_axes=("data",)):
    """prefill(params, batch) -> (cache, last_logits [B, V])."""
    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = init_cache(cfg, b, max_len)
        h = embed_tokens(params, cfg, tokens)
        positions = jnp.arange(s)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = _encode(params, cfg, batch["frames"], mesh, batch_axes)
            h = h + params["dec_pos"][:s].astype(cfg.compute_dtype)
        if cfg.family == "vlm":
            patches = batch["patches"].astype(cfg.compute_dtype)
            h = jnp.concatenate([patches, h[:, patches.shape[1]:]], axis=1)
        h, cache, _ = run_stack(
            params["blocks"], cfg, h, positions, cache=cache, cache_pos=0,
            enc_out=enc_out, mesh=mesh, batch_axes=batch_axes)
        h = _norm(params["final"], "lnf", h, cfg)
        logits = unembed(params, cfg, h[:, -1:])[:, 0]
        return cache, logits
    return prefill


def build_decode_fn(cfg: ModelConfig, mesh=None, batch_axes=("data",)):
    """decode(params, cache, tokens [B,1], pos) -> (cache, next_tok, logits)."""
    def decode(params, cache, tokens, pos):
        b, s = tokens.shape
        h = embed_tokens(params, cfg, tokens)
        positions = pos + jnp.arange(s)
        if cfg.family == "encdec":
            h = h + jax.lax.dynamic_slice_in_dim(
                params["dec_pos"], pos, s, 0).astype(cfg.compute_dtype)
        h, cache, _ = run_stack(
            params["blocks"], cfg, h, positions, cache=cache, cache_pos=pos,
            decode_cross=(cfg.family == "encdec"),
            mesh=mesh, batch_axes=batch_axes)
        h = _norm(params["final"], "lnf", h, cfg)
        logits = unembed(params, cfg, h)[:, -1]
        next_tok = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
        return cache, next_tok, logits
    return decode
