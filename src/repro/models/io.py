"""Input specifications per (config, shape-cell).

`input_specs` returns ShapeDtypeStructs (dry-run / AOT lowering, never
allocates); `make_batch` returns concrete arrays for smoke tests and real
runs; `input_axis_specs` returns the matching logical-axes pytree used to
derive NamedShardings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import stack
from repro.models.api import ModelConfig, ShapeCell


def _batch_inputs(cfg: ModelConfig, b: int, s: int):
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    return specs


def _batch_axes_tree(cfg: ModelConfig):
    axes = {"tokens": ("batch", None)}
    if cfg.family == "encdec":
        axes["frames"] = ("batch", None, None)
    if cfg.family == "vlm":
        axes["patches"] = ("batch", None, None)
    return axes


def input_specs(cfg: ModelConfig, cell: ShapeCell):
    """Abstract inputs for the cell's step function.

    train/prefill: {"batch": {...}}
    decode:        {"cache": ..., "tokens": [B,1], "pos": scalar}
    """
    if cell.kind in ("train", "prefill"):
        return {"batch": _batch_inputs(cfg, cell.global_batch, cell.seq_len)}
    return {
        "cache": stack.abstract_cache(cfg, cell.global_batch, cell.seq_len),
        "tokens": jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_axis_specs(cfg: ModelConfig, cell: ShapeCell):
    if cell.kind in ("train", "prefill"):
        return {"batch": _batch_axes_tree(cfg)}
    return {
        "cache": stack.cache_axis_specs(cfg),
        "tokens": ("batch", None),
        "pos": (),
    }


def make_batch(cfg: ModelConfig, cell: ShapeCell, key: jax.Array):
    b, s = cell.global_batch, cell.seq_len
    kt, kf = jax.random.split(key)
    batch = {"tokens": jax.random.randint(kt, (b, s), 0, cfg.vocab,
                                          jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = (jax.random.normal(
            kf, (b, cfg.enc_seq, cfg.d_model)) * 0.02).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = (jax.random.normal(
            kf, (b, cfg.n_patches, cfg.d_model)) * 0.02).astype(jnp.bfloat16)
    return batch


def smoke_cell(kind: str, b: int = 2, s: int = 32) -> ShapeCell:
    return ShapeCell(f"smoke_{kind}", s, b, kind)
