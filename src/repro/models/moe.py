"""Mixture-of-Experts FFN.

Two implementations with identical math (tested for equivalence):

- ``dense``: GShard-style one-hot dispatch/combine einsums.  Simple and
  shape-static; used as the correctness oracle and for tiny smoke configs.
- ``ep``: production expert-parallel path in ``jax.shard_map``.  Experts are
  sharded over the ``model`` mesh axis; activations arrive batch-sharded over
  (pod, data) and replicated over ``model``, so *dispatch is a local gather*
  (each model-shard already holds every token of its data shard) and combine
  is a single psum over ``model`` — the same all-reduce a TP MLP would pay.
  Expert weights are optionally ZeRO-3 sharded over ``data`` and all-gathered
  just-in-time inside the shard_map (``fsdp_experts``).

Routing: softmax top-k with normalised combine weights and a load-balancing
aux loss (Switch-style), capacity-limited with token dropping.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int                   # per-expert hidden width
    capacity_factor: float = 1.25
    impl: str = "dense"         # "dense" | "ep"
    fsdp_experts: bool = False  # ZeRO-3 gather of expert weights over "data"
    ep_axis: str = "model"
    fsdp_axis: str = "data"


def router_probs(params, x: jax.Array, spec: MoESpec):
    """x: [T, D] -> (top-k probs [T,K], top-k idx [T,K], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        params["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, spec.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch/GShard load-balance loss: E * sum_e f_e * p_e
    pe = jnp.mean(probs, axis=0)                               # [E]
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, spec.n_experts), axis=1), axis=0)
    aux = spec.n_experts * jnp.sum(pe * fe)
    return top_p, top_i, aux


def _expert_ffn(w1, w3, w2, x):
    """Batched per-expert SwiGLU: x [E, C, D]; w1/w3 [E, D, F]; w2 [E, F, D]."""
    gate = jnp.einsum("ecd,edf->ecf", x, w1.astype(x.dtype))
    up = jnp.einsum("ecd,edf->ecf", x, w3.astype(x.dtype))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("ecf,efd->ecd", h, w2.astype(x.dtype))


def _capacity(n_tokens: int, spec: MoESpec) -> int:
    c = int(n_tokens * spec.top_k * spec.capacity_factor / spec.n_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to 8 for TPU-friendly tiling


# ---------------------------------------------------------------------------
# dense (one-hot) oracle
# ---------------------------------------------------------------------------


def moe_dense(params, x: jax.Array, spec: MoESpec):
    """x: [B, S, D] -> (y, aux). One-hot dispatch; exact capacity semantics."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    top_p, top_i, aux = router_probs(params, xt, spec)
    cap = _capacity(t, spec)
    # position of each (token, k) within its expert queue
    onehot = jax.nn.one_hot(top_i, spec.n_experts, dtype=jnp.int32)  # [T,K,E]
    flat = onehot.reshape(t * spec.top_k, spec.n_experts)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                  # [T*K, E]
    pos = pos.reshape(t, spec.top_k, spec.n_experts)
    within = (pos >= 0) & (pos < cap)
    # dispatch tensor [T, E, C]
    disp = jnp.zeros((t, spec.n_experts, cap), dtype=x.dtype)
    pos_c = jnp.clip(pos, 0, cap - 1)
    disp = disp.at[
        jnp.arange(t)[:, None, None],
        jnp.broadcast_to(jnp.arange(spec.n_experts)[None, None, :],
                         pos.shape),
        pos_c,
    ].add(jnp.where(within, 1.0, 0.0).astype(x.dtype))
    combine = disp * jnp.einsum(
        "tk,tke->te", top_p.astype(x.dtype),
        onehot.astype(x.dtype))[:, :, None]
    xe = jnp.einsum("tec,td->ecd", disp, xt)                   # [E, C, D]
    ye = _expert_ffn(params["w1"], params["w3"], params["w2"], xe)
    yt = jnp.einsum("tec,ecd->td", combine, ye)
    return yt.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# expert-parallel shard_map path
# ---------------------------------------------------------------------------


def _sorted_dispatch_local(xt, top_p, top_i, e_lo, e_loc, cap,
                           spec: MoESpec):
    """Gather tokens destined for local experts [e_lo, e_lo + e_loc).

    xt: [T, D]; e_lo may be traced (axis_index), e_loc must be static.
    Returns (xe [E_loc, C, D], src_idx [E_loc, C], weight [E_loc, C]) where
    src_idx rows index into xt (clipped; weight 0 when slot empty / over
    capacity).
    """
    t = xt.shape[0]
    flat_i = top_i.reshape(-1)                                  # [T*K]
    flat_p = top_p.reshape(-1)
    flat_src = jnp.repeat(jnp.arange(t), spec.top_k)
    local = (flat_i >= e_lo) & (flat_i < e_lo + e_loc)
    # stable sort by expert id; non-local pushed to the end
    key = jnp.where(local, flat_i - e_lo, e_loc)
    order = jnp.argsort(key, stable=True)
    key_s = key[order]
    src_s = flat_src[order]
    p_s = flat_p[order]
    # rank within expert group
    same = jax.nn.one_hot(key_s, e_loc + 1, dtype=jnp.int32)
    rank = (jnp.cumsum(same, axis=0) * same).sum(-1) - 1        # [T*K]
    within = (key_s < e_loc) & (rank < cap)
    slot = jnp.where(within, key_s * cap + jnp.clip(rank, 0, cap - 1), e_loc * cap)
    src_idx = jnp.full((e_loc * cap + 1,), 0, dtype=jnp.int32)
    weight = jnp.zeros((e_loc * cap + 1,), dtype=jnp.float32)
    src_idx = src_idx.at[slot].set(jnp.where(within, src_s, 0))
    weight = weight.at[slot].add(jnp.where(within, p_s, 0.0))
    src_idx = src_idx[:-1].reshape(e_loc, cap)
    weight = weight[:-1].reshape(e_loc, cap)
    xe = xt[src_idx.reshape(-1)].reshape(e_loc, cap, -1)
    xe = xe * (weight[..., None] > 0).astype(xe.dtype)
    return xe, src_idx, weight


def moe_ep(params, x: jax.Array, spec: MoESpec, mesh: jax.sharding.Mesh,
           batch_axes=("data",)):
    """Expert-parallel MoE under shard_map over the full mesh.

    x: [B, S, D] with batch sharded over ``batch_axes`` and replicated over
    the EP axis. Expert weights w1/w3/w2: [E, D, F]/[E, D, F]/[E, F, D],
    sharded E over ``ep_axis`` (+ D or F over ``fsdp_axis`` if fsdp_experts).
    """
    b, s, d = x.shape
    ep = spec.ep_axis
    n_ep = mesh.shape[ep]
    assert spec.n_experts % n_ep == 0, (spec.n_experts, n_ep)
    e_loc = spec.n_experts // n_ep
    fsdp_w = spec.fsdp_axis if spec.fsdp_experts else None

    w_spec = P(ep, fsdp_w, None)
    w2_spec = P(ep, None, fsdp_w)
    x_spec = P(batch_axes, None, None)

    def body(wr, w1, w3, w2, xl):
        if spec.fsdp_experts:
            w1 = jax.lax.all_gather(w1, spec.fsdp_axis, axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, spec.fsdp_axis, axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, spec.fsdp_axis, axis=2, tiled=True)
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)
        top_p, top_i, aux = router_probs({"w_router": wr}, xt, spec)
        cap = _capacity(t, spec)
        idx = jax.lax.axis_index(ep)
        e_lo = idx * e_loc
        xe, src_idx, weight = _sorted_dispatch_local(
            xt, top_p, top_i, e_lo, e_loc, cap, spec)
        ye = _expert_ffn(w1, w3, w2, xe)                        # [E_loc, C, D]
        ye = ye * weight[..., None].astype(ye.dtype)
        yt = jnp.zeros((t, d), dtype=ye.dtype)
        yt = yt.at[src_idx.reshape(-1)].add(ye.reshape(-1, d))
        yt = jax.lax.psum(yt, ep)
        # aux differs per data shard and is identical across ep shards;
        # average over every mesh axis so the out_spec P() (fully
        # replicated) is semantically true.
        from repro.sharding.partition import flat_axes
        aux = jax.lax.pmean(aux, flat_axes(batch_axes) + (ep,))
        return yt.reshape(bl, sl, d), aux

    y, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), w_spec, w_spec, w2_spec, x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(params["w_router"], params["w1"], params["w3"], params["w2"], x)
    return y, aux


def moe_ffn(params, x, spec: MoESpec, mesh=None, batch_axes=("data",)):
    if spec.impl == "dense" or mesh is None:
        return moe_dense(params, x, spec)
    return moe_ep(params, x, spec, mesh, batch_axes)
