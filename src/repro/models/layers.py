"""Core neural-net layers shared by every architecture in the module zoo.

Pure-functional JAX: params are plain pytrees of arrays; every function takes
(params, inputs, config-ish kwargs) and returns arrays.  Sharding is applied
by the caller via logical-axis annotations (see repro.sharding.partition).

Attention paths:
  - full/teacher-forced:  _sdpa (reference) | _chunked_sdpa (q-block scan,
    avoids materialising S x S scores) | Pallas flash kernel
  - decode (1 token):     local cached attention, or *sequence-sharded* cache
    attention under shard_map with an online-softmax merge across shards
    (production path: works for any kv_heads vs TP degree and spreads the
    KV-cache HBM traffic across the whole mesh axis).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding import partition

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (llama-style half rotation)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, n_heads, head_dim]; positions: [seq] int32."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)             # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * inv_freq
    angles = angles[..., None, :]                            # [..., s, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                              axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    causal: bool = True
    use_rope: bool = True
    bias: bool = False
    softmax_scale: float | None = None
    attn_chunk: int = 0          # q-block size for chunked attention (0=off)
    attn_unroll: bool = False    # python-unroll the q-block loop

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def scale(self) -> float:
        return self.softmax_scale or self.head_dim ** -0.5


def _project_qkv(params, x, spec: AttentionSpec, positions):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(x.dtype))
    if spec.bias:
        q = q + params["bq"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, spec.n_heads, spec.head_dim)
    k = k.reshape(b, s, spec.n_kv_heads, spec.head_dim)
    v = v.reshape(b, s, spec.n_kv_heads, spec.head_dim)
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if spec.use_rope:
        q = apply_rope(q, positions, spec.rope_theta)
        k = apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def _scores_mask(scores, mask):
    return scores if mask is None else jnp.where(mask, scores, -1e30)


def _sdpa(q, k, v, spec: AttentionSpec, mask) -> jax.Array:
    """Reference attention. q:[B,Sq,Hq,hd] k,v:[B,Sk,Hkv,hd].

    GQA KV heads are repeated up to the q-head count so that *all* attention
    intermediates shard evenly by q-head over the TP axis (kv_heads is
    usually < TP degree; sharding by kv-head would pad and replicate the
    big [.., Sq, Sk] score tensor).  The repeat materialises g copies of
    K/V — negligible next to scores — and the Pallas kernel on real TPU
    handles GQA natively without it.
    """
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    # "seq_attn" (unsharded) not "seq": inside attention the sequence is
    # gathered and the head axis carries the TP sharding instead
    q = partition.constrain(q, ("batch", "seq_attn", "heads", None))
    k = partition.constrain(k, ("batch", "seq_attn", "heads", None))
    v = partition.constrain(v, ("batch", "seq_attn", "heads", None))
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * spec.scale
    if mask is not None:
        mask = mask.reshape(mask.shape[0], mask.shape[1],
                            *mask.shape[-2:])          # [1|B,1,Sq,Sk]
    scores = _scores_mask(scores, mask)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(b, sq, hq, hd)


def _chunked_sdpa(q, k, v, spec: AttentionSpec, q_offset, causal=True):
    """Attention evaluated per q-block so the [Sq, Sk] score matrix never
    materialises at once.  q_offset: absolute position of q[0] minus k[0]
    (for causal masking).  Falls back to python unroll when spec.attn_unroll
    (used by dry-run cost compiles so HLO counts every block)."""
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    qc = spec.attn_chunk
    pad = (-sq) % qc
    if pad:  # pad q rows; padded queries attend causally and are sliced off
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = _chunked_sdpa(q, k, v, spec, q_offset, causal)
        return out[:, :sq]
    nb = sq // qc
    kpos = jnp.arange(sk)[None, :]

    def block(qb, start):
        mask = None
        if causal:
            qpos = q_offset + start + jnp.arange(qc)[:, None]
            mask = (kpos <= qpos)[None, None, None]
        return _sdpa(qb, k, v, spec, mask)

    if spec.attn_unroll:
        outs = [block(q[:, i * qc:(i + 1) * qc], i * qc) for i in range(nb)]
        return jnp.concatenate(outs, axis=1)

    qb = q.reshape(b, nb, qc, hq, hd)

    def body(_, xs):
        qi, i = xs
        return None, block(qi, i * qc)

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qb, 1, 0), jnp.arange(nb)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, hd)


def _local_cached_attention(q, k_cache, v_cache, spec, cache_pos):
    """Single-device decode/prefill attention over a cache."""
    b, s = q.shape[0], q.shape[1]
    s_max = k_cache.shape[1]
    qi = cache_pos + jnp.arange(s)[:, None]
    ki = jnp.arange(s_max)[None, :]
    valid = (ki <= qi)[None, None, None]
    return _sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                 spec, valid)


def _flat_axes(axes) -> tuple:
    if axes is None:
        return ()
    if isinstance(axes, (tuple, list)):
        out = []
        for a in axes:
            out.extend(_flat_axes(a))
        return tuple(out)
    return (axes,)


def _n_seq_shards(mesh, rules) -> int:
    n = 1
    for a in _flat_axes(rules.get("seq_kv")):
        n *= mesh.shape[a]
    return n


def sharded_cache_attention(q, k_cache, v_cache, spec: AttentionSpec,
                            cache_pos, mesh, rules, causal=True):
    """Decode attention over a *sequence-sharded* KV cache.

    q: [B, s, Hq, hd] (replicated over the seq-shard axes); caches
    [B, S, Hkv, hd] sharded over rules["seq_kv"].  Each shard computes
    partial attention over its local cache slice; partials merge with an
    online-softmax (pmax/psum) reduction — numerically identical to global
    softmax.  This works for any (kv_heads, TP) combination and spreads
    cache HBM traffic across the mesh.
    """
    batch_axes = rules.get("batch")
    seq_axes = rules.get("seq_kv")
    seq_flat = _flat_axes(seq_axes)
    if not seq_flat:
        return _local_cached_attention(q, k_cache, v_cache, spec, cache_pos)
    n_shards = 1
    for a in seq_flat:
        n_shards *= mesh.shape[a]
    s_valid = k_cache.shape[1]
    pad = (-s_valid) % n_shards
    if pad:  # masked below via s_valid
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q_spec = P(batch_axes, None, None, None)
    kv_spec = P(batch_axes, seq_axes, None, None)

    def body(qb, kb, vb):
        bl, s, hq, hd = qb.shape
        s_loc = kb.shape[1]
        hkv = kb.shape[2]
        g = hq // hkv
        idx = jnp.zeros((), jnp.int32)
        for a in seq_flat:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        start = idx * s_loc
        qg = qb.reshape(bl, s, hkv, g, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(qb.dtype),
                            preferred_element_type=jnp.float32) * spec.scale
        kpos = start + jnp.arange(s_loc)[None, :]
        qpos = (cache_pos + jnp.arange(s))[:, None]
        mask = (kpos <= qpos) if causal else (kpos < s_valid)
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        m_loc = jnp.max(scores, axis=-1, keepdims=True)
        m_glob = jax.lax.pmax(m_loc, seq_flat)
        m_glob = jnp.maximum(m_glob, -1e30)  # all-masked guard
        p = jnp.exp(scores - m_glob)
        l_loc = jnp.sum(p, axis=-1)                          # [b,h,g,s]
        o_loc = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vb.dtype),
                           vb, preferred_element_type=jnp.float32)
        l_glob = jax.lax.psum(l_loc, seq_flat)
        o_glob = jax.lax.psum(o_loc, seq_flat)
        # l_glob [b,h,g,s] -> [b,s,h,g,1] to divide o_glob [b,s,h,g,hd]
        out = o_glob / jnp.moveaxis(l_glob, 3, 1)[..., None]
        return out.reshape(bl, s, hq, hd).astype(qb.dtype)

    return jax.shard_map(
        body, mesh=mesh, in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec, check_vma=False)(q, k_cache, v_cache)


def sharded_cache_update_attention(q, k_new, v_new, k_cache, v_cache,
                                   spec: AttentionSpec, cache_pos, mesh,
                                   rules):
    """Single-token decode with the cache update *inside* the shard_map.

    The cache sequence axis is sharded; a global dynamic_update_slice at a
    traced position makes GSPMD replicate the whole cache (measured: the
    dominant decode HBM term and the reason big-arch decode cells blew the
    16 GiB budget).  Here each shard checks whether `cache_pos` lands in
    its local slice and performs a local, in-place (donated) update; the
    attention merge is the same online-softmax as sharded_cache_attention.

    q: [B, 1, Hq, hd]; k_new/v_new: [B, 1, Hkv, hd]; caches [B, S, Hkv, hd].
    Returns (out [B,1,Hq,hd], k_cache, v_cache).
    """
    batch_axes = rules.get("batch")
    seq_axes = rules.get("seq_kv")
    seq_flat = _flat_axes(seq_axes)
    assert seq_flat, "requires a sequence-sharded cache"
    q_spec = P(batch_axes, None, None, None)
    kv_new_spec = P(batch_axes, None, None, None)
    kv_spec = P(batch_axes, seq_axes, None, None)

    def body(qb, knb, vnb, kb, vb):
        bl, s, hq, hd = qb.shape
        s_loc = kb.shape[1]
        hkv = kb.shape[2]
        g = hq // hkv
        idx = jnp.zeros((), jnp.int32)
        for a in seq_flat:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        start = idx * s_loc
        # ---- shard-local cache update ----
        local = cache_pos - start
        in_range = (local >= 0) & (local < s_loc)
        at = jnp.clip(local, 0, s_loc - 1)

        def upd(cache, new):
            old = jax.lax.dynamic_slice(cache, (0, at, 0, 0),
                                        (bl, 1, hkv, hd))
            piece = jnp.where(in_range, new.astype(cache.dtype), old)
            return jax.lax.dynamic_update_slice(cache, piece, (0, at, 0, 0))

        kb = upd(kb, knb)
        vb = upd(vb, vnb)
        # ---- partial attention + online-softmax merge ----
        qg = qb.reshape(bl, s, hkv, g, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(qb.dtype),
                            preferred_element_type=jnp.float32) * spec.scale
        kpos = start + jnp.arange(s_loc)[None, :]
        qpos = (cache_pos + jnp.arange(s))[:, None]
        scores = jnp.where((kpos <= qpos)[None, None, None], scores,
                           -jnp.inf)
        m_loc = jnp.max(scores, axis=-1, keepdims=True)
        m_glob = jnp.maximum(jax.lax.pmax(m_loc, seq_flat), -1e30)
        p = jnp.exp(scores - m_glob)
        l_loc = jnp.sum(p, axis=-1)
        o_loc = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(vb.dtype), vb,
                           preferred_element_type=jnp.float32)
        l_glob = jax.lax.psum(l_loc, seq_flat)
        o_glob = jax.lax.psum(o_loc, seq_flat)
        out = o_glob / jnp.moveaxis(l_glob, 3, 1)[..., None]
        return out.reshape(bl, s, hq, hd).astype(qb.dtype), kb, vb

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(q_spec, kv_new_spec, kv_new_spec, kv_spec, kv_spec),
        out_specs=(q_spec, kv_spec, kv_spec), check_vma=False,
    )(q, k_new, v_new, k_cache, v_cache)


def attention(params, x, spec: AttentionSpec, positions,
              attn_impl: str = "xla", kv_cache=None, cache_pos=None,
              cross_kv=None, mesh=None):
    """General attention entry point; returns (out [B,S,D], new_cache|None).

    - train / full self-attention: kv_cache is None.
    - prefill: kv_cache given, s > 1 -> attention over fresh k/v + cache fill.
    - decode: kv_cache given, s == 1 -> cached attention (sharded if the
      active partition rules shard the cache sequence axis).
    - cross attention: cross_kv = (k, v) from encoder states.
    """
    b, s, _ = x.shape
    rules = partition.active_rules()
    if cross_kv is not None:
        q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype))
        if spec.bias:
            q = q + params["bq"].astype(x.dtype)
        q = q.reshape(b, s, spec.n_heads, spec.head_dim)
        k, v = cross_kv
        if s == 1 and mesh is not None and rules is not None:
            out = sharded_cache_attention(q, k, v, spec, jnp.int32(0),
                                          mesh, rules, causal=False)
        elif spec.attn_chunk and s > spec.attn_chunk:
            out = _chunked_sdpa(q, k.astype(q.dtype), v.astype(q.dtype),
                                spec, 0, causal=False)
        else:
            out = _sdpa(q, k.astype(q.dtype), v.astype(q.dtype), spec, None)
        new_cache = None
    elif kv_cache is None:
        q, k, v = _project_qkv(params, x, spec, positions)
        if attn_impl in ("pallas", "pallas_interpret") and spec.causal:
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(
                q, k, v, causal=True, scale=spec.scale,
                interpret=(attn_impl == "pallas_interpret"))
        elif spec.attn_chunk and s > spec.attn_chunk:
            out = _chunked_sdpa(q, k, v, spec, 0, causal=spec.causal)
        else:
            mask = causal_mask(s, s) if spec.causal else None
            out = _sdpa(q, k, v, spec, mask)
        new_cache = None
    else:
        q, k, v = _project_qkv(params, x, spec, positions)
        seq_sharded = (rules is not None and mesh is not None
                       and _flat_axes(rules.get("seq_kv")))
        if s == 1 and seq_sharded and \
                kv_cache["k"].shape[1] % _n_seq_shards(mesh, rules) == 0 \
                and attn_impl == "xla":
            out, k_cache, v_cache = sharded_cache_update_attention(
                q, k, v, kv_cache["k"], kv_cache["v"], spec, cache_pos,
                mesh, rules)
            out = out.reshape(b, s, spec.q_dim)
            y = jnp.einsum("bsh,hd->bsd", out,
                           params["wo"].astype(x.dtype))
            if spec.bias:
                y = y + params["bo"].astype(x.dtype)
            return y, {"k": k_cache, "v": v_cache}
        k_cache = jax.lax.dynamic_update_slice(
            kv_cache["k"], k.astype(kv_cache["k"].dtype), (0, cache_pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            kv_cache["v"], v.astype(kv_cache["v"].dtype), (0, cache_pos, 0, 0))
        if s > 1:
            # prefill: attend over the fresh k/v (== cache content)
            if spec.attn_chunk and s > spec.attn_chunk:
                out = _chunked_sdpa(q, k, v, spec, 0, causal=True)
            else:
                out = _sdpa(q, k, v, spec, causal_mask(s, s))
        elif attn_impl in ("pallas", "pallas_interpret"):
            from repro.kernels.decode_attention import ops as da_ops
            out = da_ops.decode_attention(
                q[:, 0], k_cache, v_cache, cache_pos + s, scale=spec.scale,
                interpret=(attn_impl == "pallas_interpret"))[:, None]
        elif mesh is not None and rules is not None:
            out = sharded_cache_attention(q, k_cache, v_cache, spec,
                                          cache_pos, mesh, rules)
        else:
            out = _local_cached_attention(q, k_cache, v_cache, spec,
                                          cache_pos)
        new_cache = {"k": k_cache, "v": v_cache}
    out = out.reshape(b, s, spec.q_dim)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(x.dtype))
    # constrain right at the producer so the TP contraction lowers as a
    # reduce-scatter onto the sequence-parallel layout (not AR + slice)
    y = partition.constrain(y, ("batch", "seq", "embed_act"))
    if spec.bias:
        y = y + params["bo"].astype(x.dtype)
    return y, new_cache


def causal_mask(sq: int, sk: int, offset: int = 0) -> jax.Array:
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(sk)[None, :]
    return (ki <= qi)[None, None, None]


def cross_kv_from_encoder(params, enc: jax.Array, spec: AttentionSpec):
    b, se, _ = enc.shape
    k = jnp.einsum("bsd,dh->bsh", enc, params["wk"].astype(enc.dtype))
    v = jnp.einsum("bsd,dh->bsh", enc, params["wv"].astype(enc.dtype))
    if spec.bias:
        v = v + params["bv"].astype(enc.dtype)
    return (k.reshape(b, se, spec.n_kv_heads, spec.head_dim),
            v.reshape(b, se, spec.n_kv_heads, spec.head_dim))


# ---------------------------------------------------------------------------
# Feed-forward
# ---------------------------------------------------------------------------


def swiglu_mlp(params, x: jax.Array) -> jax.Array:
    gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
    return partition.constrain(y, ("batch", "seq", "embed_act"))


def gelu_mlp(params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    if "b_up" in params:
        h = h + params["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    y = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
    y = partition.constrain(y, ("batch", "seq", "embed_act"))
    if "b_down" in params:
        y = y + params["b_down"].astype(x.dtype)
    return y


def mlp(params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        return swiglu_mlp(params, x)
    if kind == "gelu":
        return gelu_mlp(params, x)
    raise ValueError(f"unknown mlp kind {kind!r}")
