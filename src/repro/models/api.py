"""Unified model API for the module zoo.

Every architecture is described by a ModelConfig; a declarative *param table*
(path -> ParamSpec) is the single source of truth for parameter shapes,
dtypes, logical sharding axes and initializers.  From it we derive:

  - abstract_params(cfg)        ShapeDtypeStructs (dry-run, no allocation)
  - init_params(cfg, key)       concrete params (smoke tests / real training)
  - param_specs(cfg)            logical-axes pytree (-> PartitionSpecs)

Step builders (build_loss_fn / build_prefill_fn / build_decode_fn) close over
the config and are pure jit-able functions.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers, mamba as mamba_mod, moe as moe_mod


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int               # per-expert hidden width
    every: int = 1          # MoE FFN on every `every`-th layer (1 = all)
    capacity_factor: float = 1.25
    impl: str = "dense"     # "dense" | "ep"
    fsdp_experts: bool = False
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str             # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int               # dense FFN width (0 for pure-ssm / pure-moe)
    vocab: int
    # attention flavour
    qk_norm: bool = False
    rope_theta: float = 1e4
    attn_bias: bool = False
    mlp_kind: str = "swiglu"
    norm_kind: str = "rms"          # rms | layer
    tie_embeddings: bool = False
    # family extensions
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    attn_every: int = 0             # hybrid: 1 attn layer per this many
    n_enc_layers: int = 0           # encdec
    enc_seq: int = 1500             # stub audio frontend frames
    n_patches: int = 0              # vlm stub patches
    # numerics / impl
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    kv_dtype: Any = jnp.bfloat16
    attn_impl: str = "xla"
    ssd_impl: str = "xla"
    remat: str = "none"             # none | full | dots
    loss_chunk: int = 0             # 0 = unchunked final projection
    max_pos: int = 8192             # learned-pos table size (encdec only)
    logit_softcap: float = 0.0
    attn_chunk: int = 0             # q-block size for chunked attention
    attn_unroll: bool = False       # unroll q-block loop (dry-run cost mode)
    scan_layers: bool = True

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded to a shardable multiple (Megatron-style);
        cfg.vocab stays the logical vocabulary and padded logit slots are
        masked to -inf in unembed()."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def mamba_spec(self) -> mamba_mod.MambaSpec:
        s = self.ssm or SSMConfig()
        return mamba_mod.MambaSpec(
            d_model=self.d_model, d_state=s.d_state, headdim=s.headdim,
            expand=s.expand, n_groups=s.n_groups, conv_kernel=s.conv_kernel,
            chunk=s.chunk, ssd_impl=self.ssd_impl)

    @property
    def attn_spec(self) -> layers.AttentionSpec:
        return layers.AttentionSpec(
            n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim, rope_theta=self.rope_theta,
            qk_norm=self.qk_norm, causal=True,
            use_rope=(self.family != "encdec"), bias=self.attn_bias,
            attn_chunk=self.attn_chunk, attn_unroll=self.attn_unroll)

    def layer_plan(self):
        """Returns (n_groups, per-group sub-layer plan).

        Each sub-layer is (mixer, ffn) with mixer in {attn, mamba} and ffn in
        {dense, moe, none}.  Homogeneous families have a 1-sub-layer plan
        scanned n_layers times; jamba scans super-blocks.
        """
        if self.family in ("dense", "vlm"):
            return self.n_layers, [("attn", "dense")]
        if self.family == "moe":
            assert self.moe is not None
            plan = [("attn", "moe" if (i % self.moe.every == 0) else "dense")
                    for i in range(self.moe.every)]
            assert self.n_layers % self.moe.every == 0
            return self.n_layers // self.moe.every, plan
        if self.family == "ssm":
            return self.n_layers, [("mamba", "none")]
        if self.family == "hybrid":
            assert self.attn_every > 0 and self.moe is not None
            period = self.attn_every
            attn_pos = period // 2
            plan = []
            for i in range(period):
                mixer = "attn" if i == attn_pos else "mamba"
                ffn = "moe" if (i % self.moe.every == 1) else "dense"
                plan.append((mixer, ffn))
            assert self.n_layers % period == 0
            return self.n_layers // period, plan
        if self.family == "encdec":
            return self.n_layers, [("attn", "dense")]   # decoder plan
        raise ValueError(self.family)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Param table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]    # logical axis names, len == len(shape)
    init: str = "normal"            # normal|zeros|ones|a_log|dt_bias
    dtype: Any = None               # None -> cfg.param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _attn_table(cfg: ModelConfig, cross: bool = False) -> dict:
    hq = cfg.n_heads * cfg.head_dim
    hkv = cfg.n_kv_heads * cfg.head_dim
    d = cfg.d_model
    t = {
        "wq": ParamSpec((d, hq), ("embed", "q_proj")),
        "wk": ParamSpec((d, hkv), ("embed", "kv_proj")),
        "wv": ParamSpec((d, hkv), ("embed", "kv_proj")),
        "wo": ParamSpec((hq, d), ("q_proj", "embed")),
    }
    if cfg.attn_bias:
        t["bq"] = ParamSpec((hq,), ("q_proj",), "zeros")
        t["bv"] = ParamSpec((hkv,), ("kv_proj",), "zeros")
        t["bo"] = ParamSpec((d,), ("embed",), "zeros")
    if cfg.qk_norm and not cross:
        t["q_norm"] = ParamSpec((cfg.head_dim,), (None,), "ones")
        t["k_norm"] = ParamSpec((cfg.head_dim,), (None,), "ones")
    return t


def _mlp_table(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "w_gate": ParamSpec((d, f), ("embed", "mlp")),
            "w_up": ParamSpec((d, f), ("embed", "mlp")),
            "w_down": ParamSpec((f, d), ("mlp", "embed")),
        }
    t = {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }
    if cfg.attn_bias:   # whisper-style biases everywhere
        t["b_up"] = ParamSpec((f,), ("mlp",), "zeros")
        t["b_down"] = ParamSpec((d,), ("embed",), "zeros")
    return t


def _moe_table(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    return {
        "w_router": ParamSpec((d, m.n_experts), ("embed", None)),
        "w1": ParamSpec((m.n_experts, d, m.d_ff),
                        ("expert", "embed_nofsdp" if not m.fsdp_experts
                         else "embed", "expert_mlp")),
        "w3": ParamSpec((m.n_experts, d, m.d_ff),
                        ("expert", "embed_nofsdp" if not m.fsdp_experts
                         else "embed", "expert_mlp")),
        "w2": ParamSpec((m.n_experts, m.d_ff, d),
                        ("expert", "expert_mlp",
                         "embed_nofsdp" if not m.fsdp_experts else "embed")),
    }


def _mamba_table(cfg: ModelConfig) -> dict:
    s = cfg.mamba_spec
    d = cfg.d_model
    return {
        "w_z": ParamSpec((d, s.d_inner), ("embed", "inner")),
        "w_x": ParamSpec((d, s.d_inner), ("embed", "inner")),
        "w_bc": ParamSpec((d, s.bc_dim), ("embed", None)),
        "w_dt": ParamSpec((d, s.n_heads), ("embed", "heads_ssm")),
        "dt_bias": ParamSpec((s.n_heads,), ("heads_ssm",), "dt_bias"),
        "a_log": ParamSpec((s.n_heads,), ("heads_ssm",), "a_log"),
        "d_skip": ParamSpec((s.n_heads,), ("heads_ssm",), "ones"),
        "w_conv_x": ParamSpec((s.conv_kernel, s.d_inner), (None, "inner")),
        "b_conv_x": ParamSpec((s.d_inner,), ("inner",), "zeros"),
        "w_conv_bc": ParamSpec((s.conv_kernel, s.bc_dim), (None, None)),
        "b_conv_bc": ParamSpec((s.bc_dim,), (None,), "zeros"),
        "norm_w": ParamSpec((s.d_inner,), ("inner",), "ones"),
        "w_out": ParamSpec((s.d_inner, d), ("inner", "embed")),
    }


def _norm_table(cfg: ModelConfig, name: str) -> dict:
    t = {f"{name}_w": ParamSpec((cfg.d_model,), ("embed",), "ones")}
    if cfg.norm_kind == "layer":
        t[f"{name}_b"] = ParamSpec((cfg.d_model,), ("embed",), "zeros")
    return t


def _sublayer_table(cfg: ModelConfig, mixer: str, ffn: str,
                    cross: bool = False) -> dict:
    t = {}
    t.update(_norm_table(cfg, "ln1"))
    if mixer == "attn":
        t["attn"] = _attn_table(cfg)
    else:
        t["mamba"] = _mamba_table(cfg)
    if cross:
        t.update(_norm_table(cfg, "lnx"))
        t["xattn"] = _attn_table(cfg, cross=True)
    if ffn != "none":
        t.update(_norm_table(cfg, "ln2"))
        if ffn == "dense":
            t["mlp"] = _mlp_table(cfg)
        else:
            t["moe"] = _moe_table(cfg)
    return t


def _stack_specs(tree: dict, n: int) -> dict:
    """Prepend a scanned `layers` axis of size n to every spec in tree."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.dtype)
    return jax.tree.map(f, tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_table(cfg: ModelConfig) -> dict:
    n_groups, plan = cfg.layer_plan()
    group = {}
    for i, (mixer, ffn) in enumerate(plan):
        group[f"sub{i}"] = _sublayer_table(
            cfg, mixer, ffn, cross=(cfg.family == "encdec"))
    table = {
        "embed": {"tok": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                   ("vocab", "embed"))},
        "blocks": _stack_specs(group, n_groups),
    }
    table.update({"final": _norm_table(cfg, "lnf")})
    if not cfg.tie_embeddings:
        table["lm_head"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"))
    if cfg.family == "encdec":
        enc = {"sub0": _sublayer_table(
            dataclasses.replace(cfg), "attn", "dense")}
        table["enc_blocks"] = _stack_specs(enc, cfg.n_enc_layers)
        table["enc_final"] = _norm_table(cfg, "lnf")
        table["dec_pos"] = ParamSpec((cfg.max_pos, cfg.d_model),
                                     (None, "embed"))
    return table


def _is_spec(x):
    return isinstance(x, ParamSpec)


def abstract_params(cfg: ModelConfig):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or cfg.param_dtype),
        param_table(cfg), is_leaf=_is_spec)


def param_specs(cfg: ModelConfig):
    """Pytree of logical-axes tuples, mirroring params."""
    return jax.tree.map(lambda s: s.axes, param_table(cfg), is_leaf=_is_spec)


def _init_leaf(spec: ParamSpec, key, cfg: ModelConfig):
    dtype = spec.dtype or cfg.param_dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "a_log":
        h = spec.shape[-1]
        v = jnp.log(jnp.linspace(1.0, 16.0, h))
        return jnp.broadcast_to(v, spec.shape).astype(dtype)
    if spec.init == "dt_bias":
        h = spec.shape[-1]
        dt = jnp.exp(jnp.linspace(math.log(1e-3), math.log(1e-1), h))
        v = jnp.log(jnp.expm1(dt))
        return jnp.broadcast_to(v, spec.shape).astype(dtype)
    # truncated-normal fan-in init
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = min(0.02, (1.0 / max(fan_in, 1)) ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape,
                                        jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array):
    table = param_table(cfg)
    flat, treedef = jax.tree.flatten_with_path(
        table, is_leaf=_is_spec)
    leaves = []
    for path, spec in flat:
        pstr = "/".join(str(p) for p in path)
        k = jax.random.fold_in(key, abs(hash(pstr)) % (2 ** 31))
        leaves.append(_init_leaf(spec, k, cfg))
    return jax.tree.unflatten(treedef, leaves)


def param_count(cfg: ModelConfig) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        param_table(cfg), is_leaf=_is_spec))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE counts top_k of n_experts)."""
    total = 0
    for s in jax.tree.leaves(param_table(cfg), is_leaf=_is_spec):
        n = int(np.prod(s.shape))
        total += n
    if cfg.moe is not None:
        n_groups, plan = cfg.layer_plan()
        m = cfg.moe
        expert_params = 3 * cfg.d_model * m.d_ff
        n_moe_layers = sum(1 for _, f in plan if f == "moe") * n_groups
        total -= n_moe_layers * expert_params * (m.n_experts - m.top_k)
    return total
