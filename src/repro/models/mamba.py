"""Mamba2 (state-space duality) mixer block.

Layout conventions:
  x   [B, L, H, P]   (H heads of dim P = headdim)
  B,C [B, L, G, N]   (G groups, N = d_state; G divides H)
  dt  [B, L, H]      per-head step sizes (softplus-activated)
  A   [H]            negative per-head decay rates

TP note: the input projection is stored as *separate* weights per segment
(w_z, w_x, w_bc, w_dt) rather than one fused [D, 2*d_inner+2GN+H] matrix, so
that the head-aligned dims (d_inner, H) shard cleanly over the ``model`` mesh
axis while the tiny B/C projections stay replicated.  The chunked scan itself
lives in repro.kernels.ssd_scan (Pallas kernel + pure-jnp ref).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 128
    ssd_impl: str = "xla"  # "xla" | "pallas" | "pallas_interpret"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.headdim == 0
        return self.d_inner // self.headdim

    @property
    def bc_dim(self) -> int:
        return 2 * self.n_groups * self.d_state


def _causal_conv(u, w, bias):
    """Depthwise causal conv over seq. u: [B,L,C]; w: [K, C]; bias [C]."""
    k, ch = w.shape
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w[:, None, :].astype(jnp.float32),          # [K, 1, C]
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=ch)
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(u.dtype)


def _conv_step(buf, u_new, w, bias):
    """Single-token depthwise conv. buf [B,K-1,C], u_new [B,1,C] -> [B,C].
    buf may be stored in a quantised cache dtype (e.g. f8)."""
    cache_dtype = buf.dtype
    buf = jnp.concatenate([buf.astype(u_new.dtype),
                           u_new], axis=1)          # [B, K, C]
    out = jnp.einsum("bkc,kc->bc", buf.astype(jnp.float32),
                     w.astype(jnp.float32))
    out = jax.nn.silu(out + bias.astype(jnp.float32))
    return out.astype(u_new.dtype), buf[:, 1:, :].astype(cache_dtype)


def mamba_block(params, x, spec: MambaSpec, state=None):
    """Apply the mixer.

    Train / prefill (state=None): full-sequence chunked SSD.  Returns
      (y, new_state) where new_state = (ssm_state, conv_x_tail, conv_bc_tail)
      so prefill can seed decode.
    Decode: state as above; x is [B,1,D]; returns (y, new_state).
    """
    from repro.kernels.ssd_scan import ops as ssd_ops

    bsz, seqlen, _ = x.shape
    z = jnp.einsum("bld,di->bli", x, params["w_z"].astype(x.dtype))
    xu = jnp.einsum("bld,di->bli", x, params["w_x"].astype(x.dtype))
    bc = jnp.einsum("bld,di->bli", x, params["w_bc"].astype(x.dtype))
    dt = jnp.einsum("bld,dh->blh", x, params["w_dt"].astype(x.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))           # [H]
    gn = spec.n_groups * spec.d_state

    if seqlen > 1 or state is None:
        # full-sequence chunked scan (training, or prefill into a cache);
        # an existing ssm state (all-zeros at prefill start) seeds the scan.
        initial_state = state[0] if state is not None else None
        xc = _causal_conv(xu, params["w_conv_x"], params["b_conv_x"])
        bcc = _causal_conv(bc, params["w_conv_bc"], params["b_conv_bc"])
        bi, ci = jnp.split(bcc, [gn], axis=-1)
        xi = xc.reshape(bsz, seqlen, spec.n_heads, spec.headdim)
        bi = bi.reshape(bsz, seqlen, spec.n_groups, spec.d_state)
        ci = ci.reshape(bsz, seqlen, spec.n_groups, spec.d_state)
        y, ssm_state = ssd_ops.ssd(
            xi, dt, a, bi, ci, chunk=spec.chunk, impl=spec.ssd_impl,
            initial_state=initial_state)
        y = y + xi.astype(jnp.float32) * \
            params["d_skip"].astype(jnp.float32)[None, None, :, None]
        k1 = spec.conv_kernel - 1

        def tail(u):
            t = u[:, -k1:, :]
            if seqlen < k1:
                t = jnp.pad(t, ((0, 0), (k1 - seqlen, 0), (0, 0)))
            return t
        new_state = (ssm_state, tail(xu), tail(bc))
    else:
        ssm_state, buf_x, buf_bc = state
        xc, buf_x = _conv_step(buf_x, xu, params["w_conv_x"],
                               params["b_conv_x"])
        bcc, buf_bc = _conv_step(buf_bc, bc, params["w_conv_bc"],
                                 params["b_conv_bc"])
        bi, ci = jnp.split(bcc, [gn], axis=-1)
        xi = xc.reshape(bsz, spec.n_heads, spec.headdim)
        bi = bi.reshape(bsz, spec.n_groups, spec.d_state)
        ci = ci.reshape(bsz, spec.n_groups, spec.d_state)
        dt1 = dt[:, 0]                                          # [B, H]
        decay = jnp.exp(dt1 * a[None, :])                       # [B, H]
        rep = spec.n_heads // spec.n_groups
        b_h = jnp.repeat(bi, rep, axis=1).astype(jnp.float32)   # [B, H, N]
        c_h = jnp.repeat(ci, rep, axis=1).astype(jnp.float32)
        xf = xi.astype(jnp.float32)
        ssm_state = (ssm_state * decay[..., None, None]
                     + dt1[..., None, None] * xf[..., :, None]
                     * b_h[..., None, :])                       # [B,H,P,N]
        y = jnp.einsum("bhpn,bhn->bhp", ssm_state, c_h)
        y = y + xf * params["d_skip"].astype(jnp.float32)[None, :, None]
        y = y[:, None]                                          # [B,1,H,P]
        new_state = (ssm_state, buf_x, buf_bc)

    y = y.reshape(bsz, seqlen, spec.d_inner)
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rms_norm(y.astype(x.dtype), params["norm_w"])
    out = jnp.einsum("bli,id->bld", y, params["w_out"].astype(x.dtype))
    from repro.sharding import partition
    out = partition.constrain(out, ("batch", "seq", "embed_act"))
    return out, new_state


def init_state(bsz: int, spec: MambaSpec, dtype=jnp.float32):
    k1 = spec.conv_kernel - 1
    return (jnp.zeros((bsz, spec.n_heads, spec.headdim, spec.d_state),
                      jnp.float32),
            jnp.zeros((bsz, k1, spec.d_inner), dtype),
            jnp.zeros((bsz, k1, spec.bc_dim), dtype))
