"""Production mesh builders.

make_production_mesh is a FUNCTION (not a module-level constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_slot_mesh(devices, shape, axes=("data", "model")):
    """Mesh over an explicit device subset (a FOS slot)."""
    import numpy as np
    devs = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_host_mesh():
    """Whatever devices exist on this host, as a 1-D ("data",) mesh."""
    n = jax.device_count()
    return jax.make_mesh((n,), ("data",))
