"""Serving driver: prefill + batched decode, optionally via the FOS daemon.

Single-tenant mode runs prefill+decode directly; multi-tenant mode
(`--daemon`) routes batched requests through the resource-elastic daemon
with per-tenant priorities and deadlines: an interactive tenant submits
short high-priority requests with an SLO deadline while batch tenants keep
the shell saturated, and the preemptive policy evicts batch chunks to hit
the SLO (examples/multi_tenant_serving.py shows the same path).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api, io, stack


@dataclasses.dataclass
class ServeRun:
    arch: str = "llama3.2-3b"
    reduced: bool = True
    batch: int = 4
    prompt_len: int = 32
    max_new_tokens: int = 32
    seed: int = 0


def serve(run: ServeRun, log=print) -> dict:
    cfg = configs.get(run.arch, reduced=run.reduced)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32,
                              kv_dtype=jnp.float32)
    params = api.init_params(cfg, jax.random.PRNGKey(run.seed))
    max_len = run.prompt_len + run.max_new_tokens
    prefill = jax.jit(stack.build_prefill_fn(cfg, max_len=max_len))
    decode = jax.jit(stack.build_decode_fn(cfg), donate_argnums=(1,))

    cell = io.smoke_cell("prefill", b=run.batch, s=run.prompt_len)
    batch = io.make_batch(cfg, cell, jax.random.PRNGKey(run.seed + 1))

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok[:, 0])]
    t0 = time.perf_counter()
    for i in range(run.max_new_tokens - 1):
        cache, nxt, _ = decode(params, cache, tok,
                               jnp.int32(run.prompt_len + i))
        tok = nxt[:, None]
        out_tokens.append(np.asarray(nxt))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks_per_s = (run.batch * (run.max_new_tokens - 1)) / max(t_decode, 1e-9)
    log(f"[serve] {run.arch}: prefill {t_prefill * 1e3:.1f} ms, decode "
        f"{toks_per_s:.1f} tok/s (batch={run.batch})")
    return {"prefill_s": t_prefill, "decode_tok_per_s": toks_per_s,
            "tokens": np.stack(out_tokens, axis=1)}


@dataclasses.dataclass
class DaemonServeRun:
    """Multi-tenant serving through the FOS daemon with SLO classes."""
    n_interactive: int = 6          # high-priority single-chunk requests
    n_batch: int = 2                # low-priority multi-chunk requests
    batch_chunks: int = 4
    priority_hi: int = 3
    deadline_ms: float = 2000.0     # interactive SLO (wall clock, live)
    preemptive: bool = True
    contract: bool = False          # register a QoSContract for "live"
    contract_rate_per_s: float = 50.0
    trace_out: str = ""             # write a Chrome trace here (Perfetto)
    seed: int = 0


def serve_daemon(run: DaemonServeRun, log=print) -> dict:
    """Drive the resource-elastic daemon with two SLO classes.

    Batch tenants submit long mandelbrot requests at priority 0; an
    interactive tenant submits short sobel requests at `priority_hi` with a
    deadline.  Under the preemptive policy the daemon cancels and requeues
    batch chunks when the interactive class would otherwise queue behind
    them.  With `contract=True` the live tenant additionally registers a
    `QoSContract` (deadline = `deadline_ms`, degraded mode "sobel-lite"):
    submits are screened by the admission controller, and the result dict
    carries the live SLO attainment ledger.  Returns per-class latency
    stats and the daemon counters.
    """
    from repro.core import AdmissionRejected, Daemon, ImplAlt, \
        ModuleDescriptor, PolicyConfig, QoSContract, Shell, \
        default_registry, uniform_shell
    from repro.core.daemon import _now_ms
    from repro.core.simulator import p95

    n_dev = jax.device_count()
    spec = uniform_shell(f"serve{n_dev}_s{n_dev}", (1, n_dev), n_dev)
    reg = default_registry()
    reg.register_shell(spec)
    recorder = None
    if run.trace_out:
        from repro.obs import FlightRecorder
        # wall-clock sampling: one gauge row per 100 ms of serving
        recorder = FlightRecorder(sample_every_ms=100.0)
    daemon = Daemon(Shell(spec), reg,
                    PolicyConfig(preemptive=run.preemptive),
                    obs=recorder)
    contract = None
    if run.contract:
        # the degraded tier: same sobel kernel builder, declared at a
        # cheaper estimate so the controller can swap to it when the
        # full-rate contract stops being feasible
        reg.register_module(ModuleDescriptor(
            name="sobel-lite", entrypoint="repro.core.zoo:build_sobel",
            impls=(ImplAlt("x1", 1, 2.0),), kind="fn"))
        contract = QoSContract("live", rate_per_s=run.contract_rate_per_s,
                               deadline_ms=run.deadline_ms,
                               degraded="sobel-lite")
        daemon.register_contract(contract)
    rng = np.random.default_rng(run.seed)
    re_t = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
    im_t = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
    img = rng.random((1024, 1024)).astype(np.float32)
    try:
        t0 = time.perf_counter()
        batch_handles = [
            daemon.submit(f"batch{i}", "mandelbrot",
                          [(re_t, im_t)] * run.batch_chunks, priority=0)
            for i in range(run.n_batch)]
        done_at: dict[int, float] = {}
        live_handles = []
        for _ in range(run.n_interactive):
            h = daemon.submit("live", "sobel", [(img,)],
                              priority=run.priority_hi,
                              deadline_ms=run.deadline_ms)
            # stamp completion when it happens — waiting sequentially
            # below would inflate the latency of handles that resolved
            # while an earlier result() blocked.  JobHandle.t_submit is
            # on the scheduler's millisecond clock, so stamp in ms too.
            h.future.add_done_callback(
                lambda _, rid=h.rid: done_at.setdefault(rid, _now_ms()))
            live_handles.append(h)
        rejected = 0
        for h in live_handles + batch_handles:
            try:
                h.future.result(timeout=600)
            except AdmissionRejected:
                rejected += 1       # shed by the contract screen
        live_lat = [done_at[h.rid] - h.t_submit for h in live_handles
                    if h.future.exception() is None]
        wall = time.perf_counter() - t0
        live_p95 = p95(live_lat)
        misses = sum(1 for l in live_lat if l > run.deadline_ms)
        s = daemon.stats
        slo = daemon.slo_stats if run.contract else {}
        extra = ""
        if run.contract and "live" in slo:
            lv = slo["live"]
            att = lv["attainment"]
            extra = (f", contract: {lv['admitted']} admitted / "
                     f"{lv['degraded']} degraded / "
                     f"{lv['rejected']} rejected"
                     + (f", attainment {att:.2f}"
                        if att is not None else ""))
        log(f"[serve/daemon] {n_dev} slot(s), "
            f"{'preemptive' if run.preemptive else 'cooperative'}: "
            f"live p95 {live_p95:.0f} ms "
            f"({misses}/{len(live_lat)} SLO misses), "
            f"wall {wall:.2f}s, chunks={s['chunks']} "
            f"preemptions={s['preemptions']} "
            f"reconfigs={s['reconfigurations']} reuses={s['reuses']}"
            f"{extra}")
        result = {"live_p95_ms": live_p95, "slo_misses": misses,
                  "live_rejected": rejected, "wall_s": wall,
                  "stats": dict(s), "slo": slo,
                  "metrics": daemon.metrics}
        if recorder is not None:
            from repro.obs import export_chrome_trace
            export_chrome_trace(recorder.tracer, run.trace_out)
            c = recorder.counts
            log(f"[serve/daemon] obs: {len(recorder.tracer.events)} "
                f"trace events -> {run.trace_out} (open in Perfetto); "
                f"chunks started={c['chunks_started']} "
                f"completed={c['chunks_completed']} "
                f"preempted={c['chunks_preempted']}")
        return result
    finally:
        daemon.shutdown()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--daemon", action="store_true",
                    help="multi-tenant SLO serving through the FOS daemon")
    ap.add_argument("--priority-hi", type=int, default=3)
    ap.add_argument("--deadline-ms", type=float, default=2000.0)
    ap.add_argument("--no-preempt", action="store_true")
    ap.add_argument("--contract", action="store_true",
                    help="register a QoSContract for the live tenant "
                         "(admission screening + attainment ledger)")
    ap.add_argument("--contract-rate", type=float, default=50.0,
                    help="contract target arrival rate (jobs/s)")
    ap.add_argument("--trace-out", default="",
                    help="with --daemon: attach the flight recorder and "
                         "write a Chrome trace JSON here (open in "
                         "Perfetto)")
    args = ap.parse_args()
    if args.daemon:
        serve_daemon(DaemonServeRun(priority_hi=args.priority_hi,
                                    deadline_ms=args.deadline_ms,
                                    preemptive=not args.no_preempt,
                                    contract=args.contract,
                                    contract_rate_per_s=args.contract_rate,
                                    trace_out=args.trace_out))
        return
    serve(ServeRun(arch=args.arch, batch=args.batch,
                   prompt_len=args.prompt_len,
                   max_new_tokens=args.max_new_tokens))


if __name__ == "__main__":
    main()
