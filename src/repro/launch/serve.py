"""Serving driver: prefill + batched decode, optionally via the FOS daemon.

Single-tenant mode runs prefill+decode directly; multi-tenant mode registers
the model as a FOS module and routes batched requests through the
resource-elastic daemon (examples/multi_tenant_serving.py shows that path).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api, io, stack


@dataclasses.dataclass
class ServeRun:
    arch: str = "llama3.2-3b"
    reduced: bool = True
    batch: int = 4
    prompt_len: int = 32
    max_new_tokens: int = 32
    seed: int = 0


def serve(run: ServeRun, log=print) -> dict:
    cfg = configs.get(run.arch, reduced=run.reduced)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32,
                              kv_dtype=jnp.float32)
    params = api.init_params(cfg, jax.random.PRNGKey(run.seed))
    max_len = run.prompt_len + run.max_new_tokens
    prefill = jax.jit(stack.build_prefill_fn(cfg, max_len=max_len))
    decode = jax.jit(stack.build_decode_fn(cfg), donate_argnums=(1,))

    cell = io.smoke_cell("prefill", b=run.batch, s=run.prompt_len)
    batch = io.make_batch(cfg, cell, jax.random.PRNGKey(run.seed + 1))

    t0 = time.perf_counter()
    cache, logits = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok[:, 0])]
    t0 = time.perf_counter()
    for i in range(run.max_new_tokens - 1):
        cache, nxt, _ = decode(params, cache, tok,
                               jnp.int32(run.prompt_len + i))
        tok = nxt[:, None]
        out_tokens.append(np.asarray(nxt))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks_per_s = (run.batch * (run.max_new_tokens - 1)) / max(t_decode, 1e-9)
    log(f"[serve] {run.arch}: prefill {t_prefill * 1e3:.1f} ms, decode "
        f"{toks_per_s:.1f} tok/s (batch={run.batch})")
    return {"prefill_s": t_prefill, "decode_tok_per_s": toks_per_s,
            "tokens": np.stack(out_tokens, axis=1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    args = ap.parse_args()
    serve(ServeRun(arch=args.arch, batch=args.batch,
                   prompt_len=args.prompt_len,
                   max_new_tokens=args.max_new_tokens))


if __name__ == "__main__":
    main()
