import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (XLA_FLAGS must be set before jax initialises devices)
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.configs.common import apply_cell_policy
from repro.launch import mesh as mesh_mod, roofline_model, steps
from repro.models import api
from repro.models.api import SHAPE_CELLS
from repro.sharding import hlo_analysis, partition

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / \
    "artifacts" / "dryrun"

FULL_ATTENTION_SKIP = "SKIP(full-attention): long_500k requires " \
    "sub-quadratic attention (see DESIGN.md)"


def cell_applicable(cfg, cell) -> bool:
    if cell.name == "long_500k":
        return cfg.sub_quadratic
    return True


def scale_groups(cfg, groups: int):
    """Config with `groups` layer-groups, all loops unrolled, for the cost
    extrapolation compiles (HLO cost analysis counts while-loop bodies once,
    so the roofline numbers come from unrolled g=1/g=2 compiles)."""
    _, plan = cfg.layer_plan()
    period = len(plan)
    upd = dict(n_layers=groups * period, scan_layers=False, loss_chunk=0,
               attn_unroll=True)
    if cfg.family == "encdec":
        upd["n_enc_layers"] = groups
    return dataclasses.replace(cfg, **upd)


def lower_and_compile(cfg, cell, mesh, rules, *, verbose=True):
    """Returns (compiled, info dict)."""
    step = steps.step_for_cell(cfg, cell, mesh, rules)
    shardings = steps.cell_shardings(cfg, cell, mesh, rules)
    in_sh, out_sh, donate = shardings
    args = steps.abstract_inputs(cfg, cell)
    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
    t2 = time.perf_counter()
    info = {
        "lower_s": t1 - t0,
        "compile_s": t2 - t1,
        "memory": hlo_analysis.memory_stats_dict(compiled),
        "cost": hlo_analysis.cost_analysis_dict(compiled),
    }
    if verbose:
        print(f"    memory_analysis: {compiled.memory_analysis()}")
        ca = info["cost"]
        print(f"    cost_analysis: flops={ca.get('flops', 0):.4g} "
              f"bytes={ca.get('bytes accessed', 0):.4g}")
    return compiled, info


def run_cell(arch: str, cell_name: str, *, multi_pod: bool,
             cost_extrapolate: bool = True, rule_overrides=None,
             tag: str = "", cfg_overrides: dict | None = None) -> dict:
    cell = SHAPE_CELLS[cell_name]
    base_cfg = configs.get(arch)
    if not cell_applicable(base_cfg, cell):
        return {"arch": arch, "cell": cell_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": FULL_ATTENTION_SKIP}
    cfg = apply_cell_policy(base_cfg, cell)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **{k: v for k, v in
                                          cfg_overrides.items()
                                          if k != "train_rules"})
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    kind = "train" if cell.kind == "train" else "serve"
    if kind == "train" and (cfg_overrides or {}).get(
            "train_rules") == "train_fsdp":
        kind = "train_fsdp"
    overrides = dict(rule_overrides or {})
    if cell.kind != "train" and cell.global_batch < 16:
        # batch too small to shard over "data" (e.g. long_500k b=1):
        # replicate batch, spread the cache sequence over data AND model
        overrides.setdefault("batch", None)
        overrides.setdefault(
            "seq_kv", ("pod", "data", "model") if multi_pod
            else ("data", "model"))
    rules = partition.make_rules(kind, multi_pod=multi_pod,
                                 overrides=overrides)

    result = {"arch": arch, "cell": cell_name,
              "mesh": "multi" if multi_pod else "single", "chips": chips,
              "tag": tag}
    print(f"[dryrun] {arch} x {cell_name} x "
          f"{'multi' if multi_pod else 'single'}-pod ({chips} chips)")
    compiled, info = lower_and_compile(cfg, cell, mesh, rules)
    result["full"] = info

    if cost_extrapolate:
        # two small UNROLLED compiles; while-loop bodies are counted once by
        # HLO cost analysis, so the scanned compile undercounts -- unrolled
        # g=1/g=2 compiles give exact per-layer-group slopes.
        n_groups, _ = cfg.layer_plan()
        samples = {}
        for g in (1, 2):
            gcfg = scale_groups(cfg, g)
            cmp_g, info_g = lower_and_compile(gcfg, cell, mesh, rules,
                                              verbose=False)
            hlo = cmp_g.as_text()
            samples[g] = {
                "cost": info_g["cost"],
                "coll": hlo_analysis.collective_bytes(hlo),
                "hbm_model": hlo_analysis.hbm_model_bytes(hlo),
                "by_op": hlo_analysis.bytes_by_op(hlo),
            }
            del cmp_g
        f1 = samples[1]["cost"].get("flops", 0.0)
        f2 = samples[2]["cost"].get("flops", 0.0)
        b1 = samples[1]["hbm_model"]
        b2 = samples[2]["hbm_model"]
        raw_b1 = samples[1]["cost"].get("bytes accessed", 0.0)
        raw_b2 = samples[2]["cost"].get("bytes accessed", 0.0)
        c1 = samples[1]["coll"]["total"]
        c2 = samples[2]["coll"]["total"]
        # negative slopes can appear when XLA optimises the two small
        # compiles differently; clamp to the measured g-samples
        flops_dev = max(f1 + (f2 - f1) * (n_groups - 1), f1, f2)
        bytes_dev = max(b1 + (b2 - b1) * (n_groups - 1), b1, b2)
        raw_bytes_dev = max(raw_b1 + (raw_b2 - raw_b1) * (n_groups - 1),
                            raw_b1, raw_b2)
        coll_dev = max(c1 + (c2 - c1) * (n_groups - 1), c1, c2)
        result["extrapolated"] = {
            "n_groups": n_groups,
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "raw_bytes_per_device": raw_bytes_dev,
            "coll_bytes_per_device": coll_dev,
            "g1": samples[1], "g2": samples[2],
        }
        terms = roofline_model.terms_from_costs(
            flops_dev, bytes_dev, coll_dev, chips, cfg, cell)
        result["roofline"] = terms.to_dict()
        print(f"    roofline: compute={terms.compute_s * 1e3:.2f}ms "
              f"memory={terms.memory_s * 1e3:.2f}ms "
              f"collective={terms.collective_s * 1e3:.2f}ms "
              f"dominant={terms.dominant} "
              f"frac={terms.roofline_fraction:.3f}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the cost-extrapolation compiles")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--kv-f8", action="store_true",
                    help="store KV caches in float8_e4m3 (beyond-paper)")
    ap.add_argument("--remat", default=None, choices=["none", "full",
                                                      "dots"])
    ap.add_argument("--train-rules", default="train",
                    choices=["train", "train_fsdp"])
    args = ap.parse_args()
    cfg_overrides: dict = {}
    if args.kv_f8:
        import jax.numpy as jnp
        cfg_overrides["kv_dtype"] = jnp.float8_e4m3fn
    if args.remat:
        cfg_overrides["remat"] = args.remat
    if args.train_rules != "train":
        cfg_overrides["train_rules"] = args.train_rules

    archs = configs.ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = (list(SHAPE_CELLS) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    outdir = Path(args.out)
    failures = []
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                mesh_name = "multi" if multi_pod else "single"
                fname = (f"{shape}.json" if args.tag == "baseline"
                         else f"{shape}__{args.tag}.json")
                path = outdir / mesh_name / arch / fname
                path.parent.mkdir(parents=True, exist_ok=True)
                try:
                    res = run_cell(
                        arch, shape, multi_pod=multi_pod,
                        cost_extrapolate=(not args.no_cost and not multi_pod),
                        tag=args.tag, cfg_overrides=cfg_overrides or None)
                except Exception as e:  # noqa: BLE001 - record and continue
                    traceback.print_exc()
                    res = {"arch": arch, "cell": shape, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}"}
                    failures.append((arch, shape, mesh_name))
                res["tag"] = args.tag
                path.write_text(json.dumps(res, indent=2))
    if failures:
        print(f"FAILURES ({len(failures)}): {failures}")
        raise SystemExit(1)
    print("dry-run complete: all cells compiled")


if __name__ == "__main__":
    main()
