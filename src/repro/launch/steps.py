"""Step builders that combine model, optimizer and sharding rules.

A train state is a plain dict {"params", "opt"}; its logical-axes pytree
mirrors it so NamedShardings derive mechanically.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import api, io, stack
from repro.models.api import ModelConfig, ShapeCell
from repro.optim import adamw
from repro.sharding import partition


# ---------------------------------------------------------------------------
# train state
# ---------------------------------------------------------------------------


def abstract_train_state(cfg: ModelConfig):
    params = api.abstract_params(cfg)
    zeros = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                         params)
    return {"params": params,
            "opt": {"m": zeros, "v": zeros,
                    "count": jax.ShapeDtypeStruct((), jnp.int32)}}


def init_train_state(cfg: ModelConfig, key: jax.Array):
    params = api.init_params(cfg, key)
    return {"params": params, "opt": adamw.init(params)}


def train_state_axis_specs(cfg: ModelConfig):
    axes = api.param_specs(cfg)
    return {"params": axes, "opt": {"m": axes, "v": axes, "count": ()}}


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                     mesh=None, rules: partition.AxisRules | None = None,
                     grad_compress: bool = False,
                     cast_params_once: bool = True):
    from repro.optim import grad_compress as gc

    batch_axes = rules.batch_axes if rules is not None else ("data",)
    loss_fn = stack.build_loss_fn(cfg, mesh, batch_axes=batch_axes)

    if cast_params_once:
        # mixed precision with f32 masters: cast each matrix to the compute
        # dtype ONCE, shard-local, *before* the FSDP all-gather -- halves
        # gather bytes and makes the grad reduce-scatter run in bf16 (the
        # cast transpose converts back to f32 on the shard).  1-D params
        # (norm scales, biases, a_log...) stay f32.
        base_loss_fn = loss_fn

        def loss_fn(params, batch):  # noqa: F811
            params_c = jax.tree.map(
                lambda p: p.astype(cfg.compute_dtype)
                if (hasattr(p, "ndim") and p.ndim >= 2
                    and p.dtype == jnp.float32) else p, params)
            return base_loss_fn(params_c, batch)

    def train_step(state, batch):
        with partition.use_rules(rules):
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
            if grad_compress:
                grads, ef = gc.compress_grads(grads, state["ef"])
            params, opt, metrics = adamw.update(
                opt_cfg, grads, state["opt"], state["params"])
        metrics = dict(metrics, loss=loss)
        new_state = {"params": params, "opt": opt}
        if grad_compress:
            new_state["ef"] = ef
        return new_state, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig, cell: ShapeCell, mesh=None,
                       rules: partition.AxisRules | None = None):
    batch_axes = rules.batch_axes if rules is not None else ("data",)
    prefill = stack.build_prefill_fn(cfg, max_len=cell.seq_len, mesh=mesh,
                                     batch_axes=batch_axes)

    def prefill_step(params, batch):
        with partition.use_rules(rules):
            return prefill(params, batch)

    return prefill_step


def build_decode_step(cfg: ModelConfig, mesh=None,
                      rules: partition.AxisRules | None = None):
    batch_axes = rules.batch_axes if rules is not None else ("data",)
    decode = stack.build_decode_fn(cfg, mesh=mesh, batch_axes=batch_axes)

    def decode_step(params, cache, tokens, pos):
        with partition.use_rules(rules):
            return decode(params, cache, tokens, pos)

    return decode_step


# ---------------------------------------------------------------------------
# sharding assembly for a (cfg, cell) pair
# ---------------------------------------------------------------------------


def cell_shardings(cfg: ModelConfig, cell: ShapeCell, mesh,
                   rules: partition.AxisRules):
    """Returns (in_shardings, out_shardings, donate_argnums, arg_specs)
    matching the step function for the cell kind."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())

    def shard(axes_tree):
        return partition.tree_shardings(axes_tree, mesh, rules)

    in_axis = io.input_axis_specs(cfg, cell)
    if cell.kind == "train":
        state_sh = shard(train_state_axis_specs(cfg))
        batch_sh = shard(in_axis["batch"])
        metrics_sh = {"grad_norm": rep, "lr": rep, "loss": rep}
        return ((state_sh, batch_sh), (state_sh, metrics_sh), (0,))
    if cell.kind == "prefill":
        params_sh = shard(api.param_specs(cfg))
        batch_sh = shard(in_axis["batch"])
        cache_sh = shard(stack.cache_axis_specs(cfg))
        return ((params_sh, batch_sh), (cache_sh, rep), ())
    # decode
    params_sh = shard(api.param_specs(cfg))
    cache_sh = shard(in_axis["cache"])
    tok_sh = shard(in_axis["tokens"])
    pos_sh = rep
    logits_sh = NamedSharding(
        mesh, partition.to_pspec(("batch", "vocab"), rules))
    tok_out = NamedSharding(mesh, partition.to_pspec(("batch",), rules))
    return ((params_sh, cache_sh, tok_sh, pos_sh),
            (cache_sh, tok_out, logits_sh), (1,))


def abstract_inputs(cfg: ModelConfig, cell: ShapeCell):
    """Abstract argument tuple for the cell's step function."""
    specs = io.input_specs(cfg, cell)
    if cell.kind == "train":
        return (abstract_train_state(cfg), specs["batch"])
    if cell.kind == "prefill":
        return (api.abstract_params(cfg), specs["batch"])
    return (api.abstract_params(cfg), specs["cache"], specs["tokens"],
            specs["pos"])


def step_for_cell(cfg: ModelConfig, cell: ShapeCell, mesh, rules,
                  opt_cfg: adamw.AdamWConfig | None = None):
    if cell.kind == "train":
        return build_train_step(cfg, opt_cfg or adamw.AdamWConfig(),
                                mesh, rules)
    if cell.kind == "prefill":
        return build_prefill_step(cfg, cell, mesh, rules)
    return build_decode_step(cfg, mesh, rules)
