"""End-to-end training driver.

Integrates: model zoo + sharding rules + AdamW + data pipeline + async
checkpointing + watchdog/fault-injection restarts + optional gradient
compression + FOS elastic re-partitioning (save -> rebuild with a new rule
set / mesh -> elastic restore -> continue).

CPU-friendly by default (reduced configs); the same driver lowers the full
assigned configs on the production mesh via --production (dry-run compile
covered by launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.ckpt.fault import FaultInjector, InjectedFault, StepTimeout, \
    Watchdog, run_with_restarts
from repro.data.pipeline import DataConfig, Pipeline
from repro.launch import steps as steps_mod
from repro.models import api
from repro.optim import adamw, grad_compress as gc
from repro.sharding import partition


@dataclasses.dataclass
class TrainRun:
    arch: str = "llama3.2-3b"
    reduced: bool = True
    steps: int = 30
    global_batch: int = 8
    seq_len: int = 64
    lr: float = 1e-3
    ckpt_dir: str | None = None
    ckpt_every: int = 10
    resume: bool = False
    grad_compress: bool = False
    fail_at_step: int | None = None
    elastic_switch_step: int | None = None   # re-partition mid-run
    watchdog_timeout_s: float = 300.0
    log_every: int = 5
    seed: int = 0


def _mesh_and_rules(elastic_phase: int = 0):
    n = jax.device_count()
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    # elastic phase 1 flips the FSDP rule — restoring across phases
    # exercises reshard-on-restore (the FOS replacement primitive)
    overrides = {"embed": None} if elastic_phase else None
    rules = partition.make_rules("train", overrides=overrides)
    return mesh, rules


def _build(cfg, run: TrainRun, mesh, rules):
    opt_cfg = adamw.AdamWConfig(lr=run.lr, warmup_steps=5,
                                total_steps=max(run.steps, 10))
    step_fn = steps_mod.build_train_step(cfg, opt_cfg, mesh, rules,
                                         grad_compress=run.grad_compress)
    state_axes = steps_mod.train_state_axis_specs(cfg)
    if run.grad_compress:
        state_axes = dict(state_axes, ef=api.param_specs(cfg))
    state_sh = partition.tree_shardings(state_axes, mesh, rules)
    batch_sh = partition.tree_shardings({"tokens": ("batch", None)},
                                        mesh, rules)
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    return jitted, state_sh


def _init_state(cfg, run: TrainRun, state_sh):
    state = steps_mod.init_train_state(cfg, jax.random.PRNGKey(run.seed))
    if run.grad_compress:
        state["ef"] = gc.init_error_feedback(state["params"])
    return jax.device_put(state, state_sh)


def train(run: TrainRun, log=print) -> dict:
    cfg = configs.get(run.arch, reduced=run.reduced)
    cfg = dataclasses.replace(cfg, loss_chunk=0, remat="none",
                              scan_layers=True)
    mgr = CheckpointManager(run.ckpt_dir) if run.ckpt_dir else None
    injector = FaultInjector(run.fail_at_step)
    history: dict = {"loss": [], "restarts": 0, "elastic_switches": 0,
                     "steps_per_sec": 0.0}

    def run_fn(start_step: int) -> int:
        phase = 1 if (run.elastic_switch_step is not None
                      and start_step >= run.elastic_switch_step) else 0
        mesh, rules = _mesh_and_rules(phase)
        with jax.set_mesh(mesh):
            return _run_phase(start_step, phase, mesh, rules)

    def _run_phase(start_step: int, phase: int, mesh, rules) -> int:
        jitted, state_sh = _build(cfg, run, mesh, rules)
        if mgr is not None and (run.resume or start_step > 0) \
                and mgr.latest_step() is not None:
            ck = mgr.latest_step()
            like = jax.eval_shape(lambda: steps_mod.init_train_state(
                cfg, jax.random.PRNGKey(run.seed)))
            if run.grad_compress:
                like["ef"] = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                    like["params"])
            state = mgr.restore(ck, like, state_sh)
            start = ck
            log(f"[train] restored step {ck} (phase {phase})")
        else:
            state = _init_state(cfg, run, state_sh)
            start = 0
        data = Pipeline(DataConfig(cfg.vocab, run.seq_len,
                                   run.global_batch, seed=run.seed),
                        start_step=start)
        wd = Watchdog(run.watchdog_timeout_s,
                      on_timeout=lambda: log("[train] WATCHDOG timeout"))
        wd.start()
        t0 = time.perf_counter()
        try:
            for step, batch in data:
                if step >= run.steps:
                    break
                if (run.elastic_switch_step is not None and phase == 0
                        and step >= run.elastic_switch_step):
                    if mgr is not None:
                        mgr.save(step, state, blocking=True)
                    history["elastic_switches"] += 1
                    log(f"[train] elastic re-partition at step {step}")
                    return step          # supervisor re-enters in phase 1
                injector.check(step)
                wd.beat()
                if wd.fired:
                    raise StepTimeout(f"straggler at step {step}")
                state, metrics = jitted(state, batch)
                if step % run.log_every == 0 or step == run.steps - 1:
                    loss = float(metrics["loss"])
                    history["loss"].append((step, loss))
                    log(f"[train] step {step} loss {loss:.4f} "
                        f"gnorm {float(metrics['grad_norm']):.3f}")
                if mgr is not None and step and step % run.ckpt_every == 0:
                    mgr.save(step, state)
            dt = time.perf_counter() - t0
            history["steps_per_sec"] = (run.steps - start) / max(dt, 1e-9)
            if mgr is not None:
                mgr.save(run.steps, state, blocking=True)
                mgr.wait()
            return run.steps
        except InjectedFault:
            if mgr is not None:
                mgr.wait()
            raise
        finally:
            wd.stop()
            data.close()

    def supervised(start: int) -> int:
        step = start
        while step < run.steps:
            step = run_fn(step)
        return step

    final, restarts = run_with_restarts(supervised, log=log)
    history["restarts"] = restarts
    history["final_step"] = final
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=configs.ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (not reduced)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--elastic-switch-step", type=int, default=None)
    args = ap.parse_args()
    run = TrainRun(arch=args.arch, reduced=not args.full, steps=args.steps,
                   global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                   ckpt_dir=args.ckpt_dir, resume=args.resume,
                   grad_compress=args.grad_compress,
                   fail_at_step=args.fail_at_step,
                   elastic_switch_step=args.elastic_switch_step)
    hist = train(run)
    print(f"[train] done: {hist['final_step']} steps, "
          f"{hist['steps_per_sec']:.2f} steps/s, "
          f"restarts={hist['restarts']}, "
          f"final loss={hist['loss'][-1][1]:.4f}")


if __name__ == "__main__":
    main()
