"""Roofline model for the TPU v5e-class target.

Hardware constants (per assignment):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM per chip, ~50 GB/s/link ICI.

Terms (seconds), per (arch x mesh), derived from the compiled dry-run:
  compute    = HLO_FLOPs_global   / (chips * PEAK_FLOPS)
  memory     = HLO_bytes_global   / (chips * HBM_BW)
  collective = coll_bytes_global  / (chips * ICI_BW)

cost_analysis() reports *per-device* numbers for the partitioned program, so
global = per_device * chips; the divisions above then cancel back to
per-device seconds, which is what we report.
"""
from __future__ import annotations

import dataclasses

from repro.models import api
from repro.models.api import ModelConfig, ShapeCell

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW_PER_LINK = 50e9       # B/s per link
ICI_LINKS = 1                # conservative: single-link serialisation
HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_global: float
    useful_flops_ratio: float     # MODEL_FLOPS / (HLO_FLOPs * chips)
    chips: int = 256
    memory_kernel_adj_s: float = 0.0   # memory term with Pallas-kernel
    #                                    score traffic removed (see
    #                                    scores_traffic_bytes)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s,
                 "memory": self.memory_kernel_adj_s or self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap roofline step time: max of the three terms,
        memory taken kernel-adjusted when available."""
        return max(self.compute_s,
                   self.memory_kernel_adj_s or self.memory_s,
                   self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of ideal compute roofline achieved if the step runs at
        step_time_s: ideal = MODEL_FLOPS/(chips*peak)."""
        if self.step_time_s == 0:
            return 0.0
        ideal = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return ideal / self.step_time_s

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_kernel_adj_s": self.memory_kernel_adj_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS = 6*N*D (train), 2*N*D (prefill), 2*N*B (decode: one
    token/sequence), with N = active params (MoE: top-k of experts)."""
    n = api.active_param_count(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch


def scores_traffic_bytes(cfg: ModelConfig, cell: ShapeCell,
                         chips: int) -> float:
    """Per-device HBM bytes the XLA attention/SSD path spends on
    materialised score/prob tensors, which the validated Pallas kernels
    (flash_attention / ssd_scan: blockwise, VMEM-resident) never write.

    Per score element: fwd ~12 B (f32 scores w+r, bf16 probs w+r);
    bwd with remat="full" ~20 B more.  train=32 B/elem, prefill=12,
    decode=0 (flash-decode streams KV only).
    """
    if cell.kind == "decode":
        return 0.0
    per_elem = 32.0 if cell.kind == "train" else 12.0
    b, s = cell.global_batch, cell.seq_len
    n_groups, plan = cfg.layer_plan()
    elems = 0.0
    n_attn = sum(1 for mix, _ in plan if mix == "attn") * n_groups
    n_ssm = sum(1 for mix, _ in plan if mix == "mamba") * n_groups
    if n_attn:
        elems += n_attn * b * cfg.n_heads * s * s * 0.5     # causal
    if cfg.family == "encdec":
        enc = cfg.enc_seq
        elems += cfg.n_enc_layers * b * cfg.n_heads * enc * enc
        elems += cfg.n_layers * b * cfg.n_heads * s * enc   # cross
    if n_ssm:
        ms = cfg.mamba_spec
        elems += n_ssm * b * ms.n_heads * s * ms.chunk      # intra-chunk
    return per_elem * elems / chips


def terms_from_costs(flops_dev: float, bytes_dev: float,
                     coll_bytes_dev: float, chips: int,
                     cfg: ModelConfig, cell: ShapeCell) -> RooflineTerms:
    mf = model_flops(cfg, cell)
    adj = max(bytes_dev - scores_traffic_bytes(cfg, cell, chips), 0.0)
    return RooflineTerms(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=bytes_dev / HBM_BW,
        memory_kernel_adj_s=adj / HBM_BW,
        collective_s=coll_bytes_dev / (ICI_BW_PER_LINK * ICI_LINKS),
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        coll_bytes_per_device=coll_bytes_dev,
        model_flops_global=mf,
        useful_flops_ratio=(mf / (flops_dev * chips)) if flops_dev else 0.0,
        chips=chips,
    )
