"""Flight recorder: counters, sampled gauges, scheduler self-profile.

One :class:`FlightRecorder` attaches to one `Fabric`
(``recorder.attach(fabric)`` sets ``fabric.obs``); the fabric and
simulator then feed it through a handful of duck-typed hooks guarded by
a single ``if self.obs is not None:`` test, so the detached path costs
nothing and stays byte-identical.  Three surfaces:

- **counters** — monotonic event counts (submit verdicts, chunk
  lifecycle, steal probe outcomes) plus per-tenant service-ms, built so
  conservation holds by construction: every probe is exactly one hit or
  miss, every submit exactly one of admitted/degraded/rejected, every
  started chunk completes or is preempted;
- **sampler** — AutoCounter-style periodic gauge reads (occupancy,
  pending chunks, effective reserve, a counters copy) into a bounded
  ring-buffer history, on the caller's clock (sim time under the
  simulator, daemon wall time live);
- **prof** — per-`schedule()`-pass self-profiling of the incremental
  core: shells visited vs. elided, `_backlog_ms` memo hits/misses,
  steal-fail-cache skips, event-heap compactions.

All timestamps are injected by the caller; this module is declared a
schedlint sim module and never reads ambient time or randomness.
"""

from __future__ import annotations

import collections

from repro.obs import trace as tr
from repro.obs.trace import Tracer

SCHEDLINT_SIM = True

# counters a fresh recorder starts from; kept as a tuple literal so the
# conservation identities below are easy to audit:
#   submitted            == admitted + degraded + rejected
#   steal_probes         == steal_hits + steal_misses
#   chunks_started       == chunks_completed + chunks_preempted (at rest)
COUNTER_NAMES = (
    "submitted", "admitted", "degraded", "rejected",
    "jobs_dispatched",
    "chunks_started", "chunks_completed", "chunks_preempted",
    "steal_probes", "steal_hits", "steal_misses", "stolen_chunks",
    "ckpt_saves", "ckpt_migrations",
    "reconfigs", "reserve_resizes",
    # link-network transfers (zero on the uniform scalar shim):
    #   transfers_started    == transfers_completed (at rest)
    #   transfers_queued     <= transfers_started
    "transfers_started", "transfers_completed", "transfers_queued",
)

PROF_KEYS = (
    "passes", "shells_visited", "shells_elided",
    "backlog_hits", "backlog_misses",
    "steal_cache_hits", "heap_compactions",
)


class CounterSampler:
    """Periodic gauge reader with a bounded history.

    ``maybe_sample(now_ms, gauges)`` takes at most one row per
    ``interval_ms`` window; after a quiet stretch the next due time
    jumps past every missed window (integer arithmetic on the gap — no
    catch-up rows, no float drift), so sampling is deterministic in the
    caller's clock.
    """

    def __init__(self, interval_ms: float, history_max: int = 1024):
        if interval_ms <= 0:
            raise ValueError("interval_ms must be positive")
        if history_max <= 0:
            raise ValueError("history_max must be positive")
        self.interval_ms = float(interval_ms)
        self.history: collections.deque[dict] = collections.deque(
            maxlen=history_max)
        self._next_t: float | None = None

    def maybe_sample(self, now_ms: float, gauges_fn) -> bool:
        """``gauges_fn`` is called only when a sample is actually due,
        so the per-pass cost of a quiet sampler is one float compare."""
        if self._next_t is None:
            self._next_t = now_ms
        if now_ms < self._next_t:
            return False
        row = {"t_ms": now_ms}
        row.update(gauges_fn())
        self.history.append(row)
        missed = int((now_ms - self._next_t) // self.interval_ms)
        self._next_t += (missed + 1) * self.interval_ms
        return True


class FlightRecorder:
    """The observability head: tracer + counters + sampler + profiler.

    Construction chooses the surfaces: ``trace=False`` drops the event
    buffer (counters and profiling still run), ``sample_every_ms=None``
    (the default) disables periodic gauge sampling.  Attach with
    :meth:`attach`; read everything back with :meth:`snapshot`, which
    is what lands in ``SimResult.metrics`` / ``Daemon.metrics``.
    """

    def __init__(self, trace: bool = True, max_events: int = 1 << 18,
                 sample_every_ms: float | None = None,
                 history_max: int = 1024):
        self.tracer = Tracer(max_events) if trace else None
        self.sampler = (CounterSampler(sample_every_ms, history_max)
                        if sample_every_ms is not None else None)
        self.counts: dict[str, int] = {k: 0 for k in COUNTER_NAMES}
        self.tenant_service_ms: dict[str, float] = {}
        self.prof: dict[str, int] = {k: 0 for k in (
            "passes", "shells_visited", "shells_elided",
            "heap_compactions")}
        # hottest per-event tallies live as plain attributes, not dict
        # entries: the fabric bumps these inline (one attribute
        # increment, no method call, no hashing) on paths that fire
        # tens of thousands of times per second on saturated fabrics —
        # backlog-memo lookups and fingerprint-cache steal skips.  A
        # cache skip is a probe that missed ("nothing changed since the
        # last failed scan") and is counted as such at snapshot time,
        # but never traced: verbatim events here would dominate the
        # buffer on steal-heavy traces.  snapshot()/gauges() fold all
        # three back into the profile/counter dicts.
        self.backlog_hits = 0
        self.backlog_misses = 0
        self.steal_fp_skips = 0
        self.fabric = None

    # -- wiring --------------------------------------------------------

    def attach(self, fabric) -> "FlightRecorder":
        """Wire this recorder into ``fabric`` (one fabric per recorder).

        Sets ``fabric.obs`` and hooks every shell's ``on_reserve``
        callback so reserve resizes are recorded even when sampling is
        off.  Returns self for chaining.
        """
        if self.fabric is not None:
            raise ValueError("recorder is already attached to a fabric")
        if getattr(fabric, "obs", None) is not None:
            raise ValueError("fabric already has a recorder attached")
        self.fabric = fabric
        fabric.obs = self
        for name, st in fabric.states.items():
            st.on_reserve = (lambda nm: lambda t, r: self.on_reserve(
                nm, t, r))(name)
        return self

    # -- hooks (called by Fabric/simulate; obs is None when detached) --

    def on_submit(self, job, now: float) -> None:
        c = self.counts
        c["submitted"] += 1
        if job.rejected:
            c["rejected"] += 1
        elif job.degraded_from is not None:
            c["degraded"] += 1
        else:
            c["admitted"] += 1
        if self.tracer is not None:
            data = {"module": job.module, "n_chunks": job.n_chunks,
                    "priority": job.priority}
            if job.verdict is not None:
                data["verdict"] = job.verdict.action
            if job.degraded_from is not None:
                data["degraded_from"] = job.degraded_from
            self.tracer.emit(now, tr.SUBMIT, rid=job.gid,
                             tenant=job.tenant, data=data)

    def on_dispatch(self, job, shell: str, now: float) -> None:
        self.counts["jobs_dispatched"] += 1
        if self.tracer is not None:
            self.tracer.emit(now, tr.DISPATCH, shell=shell, rid=job.gid,
                             tenant=job.tenant,
                             data={"module": job.module})

    def on_pass(self, now: float, run, n_shells: int, out) -> None:
        """One completed ``Fabric.schedule`` pass.

        ``run`` is the visited (dirty) shell set, ``out`` the issued
        ``(shell, Assignment)`` list.
        """
        p = self.prof
        p["passes"] += 1
        p["shells_visited"] += len(run)
        p["shells_elided"] += n_shells - len(run)
        c = self.counts
        c["chunks_started"] += len(out)
        tracer = self.tracer
        for shell, a in out:
            if a.reconfigure:
                c["reconfigs"] += 1
            if tracer is None:
                continue
            data = {"module": a.module, "frac": a.frac}
            if a.restore_ms:
                data["restore_ms"] = a.restore_ms
            if a.save_ms:
                data["save_ms"] = a.save_ms
            if a.reconfigure:
                data["reconfigure"] = True
                tracer.emit(now, tr.RECONFIG, shell=shell,
                            data={"module": a.module})
            tracer.emit(now, tr.CHUNK_START, shell=shell, rid=a.rid,
                        chunk=a.chunk, aid=a.aid, data=data)
            if a.frac < 1.0 or a.restore_ms:
                tracer.emit(now, tr.CKPT_RESTORE, shell=shell, rid=a.rid,
                            chunk=a.chunk, aid=a.aid,
                            data={"frac": a.frac})
        if tracer is not None and out:
            # counts only (the visited set itself would be an O(dirty)
            # allocation per pass), and only for passes that issued
            # work — the every-pass visited/elided totals live in the
            # profile, so quiet passes need no event
            tracer.emit(now, tr.SCHED_PASS, data={
                "n_visited": len(run),
                "n_elided": n_shells - len(run), "issued": len(out)})
        if self.sampler is not None:
            self.sampler.maybe_sample(now, self.gauges)

    def on_complete(self, shell: str, a, tenant: str, now: float) -> None:
        self.counts["chunks_completed"] += 1
        # slot-ms: wall duration of the chunk times the slots it held —
        # the fairness currency THEMIS-style accounting needs
        self.tenant_service_ms[tenant] = self.tenant_service_ms.get(
            tenant, 0.0) + (now - a.t_start) * a.rng.size
        if self.tracer is not None:
            self.tracer.emit(now, tr.CHUNK_COMPLETE, shell=shell,
                             rid=a.rid, chunk=a.chunk, aid=a.aid,
                             tenant=tenant, data={"t_start": a.t_start})

    def on_preempted(self, pairs, now: float) -> None:
        """``pairs`` is Fabric.drain_preempted's ``(shell, Assignment)``
        list; checkpoint saves are attributed here because eviction is
        the instant the save cost is modeled."""
        c = self.counts
        fab = self.fabric
        for shell, a in pairs:
            c["chunks_preempted"] += 1
            saved = (fab is not None and fab.ckpt is not None
                     and fab.ckpt_capable.get(shell, False)
                     and not fab.states[shell].requests[a.rid].failed)
            if saved:
                c["ckpt_saves"] += 1
            if self.tracer is not None:
                self.tracer.emit(now, tr.PREEMPT, shell=shell, rid=a.rid,
                                 chunk=a.chunk, aid=a.aid,
                                 data={"t_start": a.t_start,
                                       "saved": saved})
                if saved:
                    self.tracer.emit(now, tr.CKPT_SAVE, shell=shell,
                                     rid=a.rid, chunk=a.chunk, aid=a.aid)

    def on_steal(self, victim: str, thief: str, now: float, hit: bool,
                 chunks: int = 0) -> None:
        c = self.counts
        c["steal_probes"] += 1
        if hit:
            c["steal_hits"] += 1
            c["stolen_chunks"] += chunks
        else:
            c["steal_misses"] += 1
        if self.tracer is not None:
            data = {"victim": victim, "thief": thief}
            if hit:
                data["chunks"] = chunks
            self.tracer.emit(now, tr.STEAL_HIT if hit else tr.STEAL_MISS,
                             shell=thief, data=data)

    def on_ckpt_migrate(self, victim: str, thief: str, rid: int,
                        now: float) -> None:
        self.counts["ckpt_migrations"] += 1
        if self.tracer is not None:
            self.tracer.emit(now, tr.CKPT_MIGRATE, shell=thief, rid=rid,
                             data={"victim": victim, "thief": thief})

    def on_transfer_start(self, victim: str, thief: str, chunks: int,
                          xfer, now: float) -> None:
        """A steal reserved link occupancy (`xfer` is the
        `core.network.Transfer` receipt); only fires on an active link
        network — the uniform shim realizes no transfers."""
        c = self.counts
        c["transfers_started"] += 1
        queued = xfer.wait_ms > 0.0
        if queued:
            c["transfers_queued"] += 1
        if self.tracer is not None:
            if queued:
                self.tracer.emit(now, tr.TRANSFER_QUEUED, shell=thief,
                                 data={"victim": victim, "thief": thief,
                                       "wait_ms": xfer.wait_ms})
            self.tracer.emit(now, tr.TRANSFER_START, shell=thief,
                             data={"victim": victim, "thief": thief,
                                   "chunks": chunks,
                                   "transfer_ms": xfer.total_ms})

    def on_transfer_complete(self, victim: str, thief: str,
                             now: float) -> None:
        self.counts["transfers_completed"] += 1
        if self.tracer is not None:
            self.tracer.emit(now, tr.TRANSFER_COMPLETE, shell=thief,
                             data={"victim": victim, "thief": thief})

    def on_reserve(self, shell: str, now: float, slots: int) -> None:
        self.counts["reserve_resizes"] += 1
        if self.tracer is not None:
            self.tracer.emit(now, tr.RESERVE, shell=shell,
                             data={"slots": slots})

    # -- gauges / snapshot --------------------------------------------

    def _counters(self) -> dict:
        """Counter copy with the fingerprint-cache skips folded in:
        each skip is one probe and one miss, so the conservation
        identity `probes == hits + misses` survives the fold."""
        c = dict(self.counts)
        c["steal_probes"] += self.steal_fp_skips
        c["steal_misses"] += self.steal_fp_skips
        return c

    def gauges(self) -> dict:
        """Instantaneous fabric-wide gauges plus a counters copy
        (firesim AutoCounter reads the counter file the same way: the
        sample is the running total, rates are first differences)."""
        busy = total = pend = reserve = 0
        fab = self.fabric
        if fab is not None:
            for st in fab.states.values():
                busy += len(st.alloc.busy)
                total += st.alloc.n
                pend += st.pending_chunks()
                reserve += st._reserve_last
        row = {"occupancy": busy / total if total else 0.0,
               "pending_chunks": pend,
               "effective_reserve": reserve,
               "counters": self._counters()}
        if fab is not None and fab.network.active:
            # link-utilisation gauges (count-based, no clock needed);
            # keys only exist on link-network runs so uniform-shim
            # sample rows stay byte-identical to PR 9
            row.update(fab.network.gauges())
        return row

    def snapshot(self) -> dict:
        """JSON-able metrics dict: the `SimResult.metrics` /
        `Daemon.metrics["obs"]` payload."""
        prof = dict(self.prof)
        prof["backlog_hits"] = self.backlog_hits
        prof["backlog_misses"] = self.backlog_misses
        prof["steal_cache_hits"] = self.steal_fp_skips
        seen = prof["shells_visited"] + prof["shells_elided"]
        prof["elision_rate"] = (prof["shells_elided"] / seen
                                if seen else 0.0)
        lookups = prof["backlog_hits"] + prof["backlog_misses"]
        prof["backlog_hit_rate"] = (prof["backlog_hits"] / lookups
                                    if lookups else 0.0)
        counters = self._counters()
        probes = counters["steal_probes"]
        prof["steal_cache_hit_rate"] = (prof["steal_cache_hits"] / probes
                                        if probes else 0.0)
        out = {"counters": counters,
               "tenant_service_ms": dict(self.tenant_service_ms),
               "profile": prof}
        if self.sampler is not None:
            out["samples"] = [dict(row) for row in self.sampler.history]
        if self.tracer is not None:
            out["trace"] = {"events": len(self.tracer.events),
                            "dropped": self.tracer.dropped}
        fab = self.fabric
        if fab is not None:
            if fab.ckpt is not None:
                out["ckpt"] = dict(fab.ckpt.stats)
            if fab.slo is not None:
                out["admission"] = fab.slo.totals()
            if fab.network.active:
                # per-link lifetime stats (transfers, busy_ms,
                # max_queue), keyed "src->dst"; absent on the uniform
                # shim so pre-network snapshots are unchanged
                out["network"] = fab.network.stats()
        return out
