"""Chrome-trace-event exporter: open the result in Perfetto
(https://ui.perfetto.dev) or chrome://tracing.

The layout is one process ("fos"), one thread lane per shell plus a
``fabric`` lane (tid 0) for fabric-scope events (submits, steal
probes, scheduler passes).  ``chunk_start`` events are paired with
their ``chunk_complete``/``preempt`` partner by assignment id into "X"
(complete) duration events; every other kind renders as a thread
instant.  Trace timestamps are microseconds, so sim-time milliseconds
are multiplied by 1000.

This module does file I/O and stamps the capture time into
``otherData`` — it is *not* a sim module, and its wall-clock read is
allowlisted in `analysis/config.py` (the stamp annotates the artifact;
nothing feeds back into scheduling).
"""

from __future__ import annotations

import json
import time

from repro.obs import trace as tr

# event kinds whose span pairing the exporter understands
_SPAN_OPEN = tr.CHUNK_START
_SPAN_CLOSE = (tr.CHUNK_COMPLETE, tr.PREEMPT)


def chrome_trace(events, shells=None, dropped: int = 0) -> dict:
    """Build the Chrome trace dict from an iterable of TraceEvents.

    ``shells`` optionally fixes the lane order; by default lanes appear
    in first-event order, sorted for determinism.
    """
    events = list(events)
    if shells is None:
        lanes: dict[str, None] = {}
        for e in events:
            if e.shell is not None and e.shell not in lanes:
                lanes[e.shell] = None
        shells = sorted(lanes)
    tid = {"fabric": 0}
    for i, name in enumerate(shells):
        tid[name] = i + 1

    out = [{"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
            "args": {"name": "fos"}},
           {"ph": "M", "name": "thread_name", "pid": 1, "tid": 0,
            "args": {"name": "fabric"}}]
    for name in shells:
        out.append({"ph": "M", "name": "thread_name", "pid": 1,
                    "tid": tid[name], "args": {"name": name}})

    open_by_aid: dict[int, tr.TraceEvent] = {}
    for e in events:
        lane = tid.get(e.shell, 0)
        if e.kind == _SPAN_OPEN:
            open_by_aid[e.aid] = e
            continue
        if e.kind in _SPAN_CLOSE:
            start = open_by_aid.pop(e.aid, None)
            t0 = (start.t_ms if start is not None
                  else (e.data or {}).get("t_start", e.t_ms))
            args = {"rid": e.rid, "chunk": e.chunk, "aid": e.aid}
            if start is not None and start.data:
                args.update(start.data)
            if e.tenant is not None:
                args["tenant"] = e.tenant
            if e.kind == tr.PREEMPT:
                args["preempted"] = True
            name = args.get("module", "chunk")
            out.append({"ph": "X", "name": f"{name} r{e.rid}.c{e.chunk}",
                        "cat": "chunk", "pid": 1, "tid": lane,
                        "ts": t0 * 1000.0,
                        "dur": (e.t_ms - t0) * 1000.0, "args": args})
            continue
        args = {}
        if e.rid is not None:
            args["rid"] = e.rid
        if e.tenant is not None:
            args["tenant"] = e.tenant
        if e.data:
            args.update(e.data)
        out.append({"ph": "i", "s": "t", "name": e.kind, "cat": e.kind,
                    "pid": 1, "tid": lane, "ts": e.t_ms * 1000.0,
                    "args": args})

    # chunks still in flight when the trace was captured (live daemon
    # snapshots): render as open "B" markers so the lane shows them
    for aid, start in open_by_aid.items():
        out.append({"ph": "B", "name": f"r{start.rid}.c{start.chunk}",
                    "cat": "chunk", "pid": 1,
                    "tid": tid.get(start.shell, 0),
                    "ts": start.t_ms * 1000.0,
                    "args": {"rid": start.rid, "chunk": start.chunk,
                             "aid": aid}})

    out.sort(key=lambda ev: (ev["ph"] != "M", ev.get("ts", 0.0)))
    return {"traceEvents": out,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": dropped,
                          "captured_unix_s": time.time()}}


def export_chrome_trace(source, path: str | None = None,
                        shells=None) -> dict:
    """Render ``source`` (a Tracer or an iterable of TraceEvents) to a
    Chrome trace dict, writing JSON to ``path`` when given."""
    dropped = getattr(source, "dropped", 0)
    events = getattr(source, "events", source)
    doc = chrome_trace(events, shells=shells, dropped=dropped)
    if path:
        with open(path, "w") as fh:
            json.dump(doc, fh)
    return doc
