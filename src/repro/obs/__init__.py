"""repro.obs — the fabric flight recorder (PR 9).

Strictly opt-in observability for the scheduling fabric: structured
event tracing (`Tracer`, Chrome-trace export), AutoCounter-style
sampled counters (`CounterSampler`), and scheduler self-profiling, all
behind one `FlightRecorder` attached via ``recorder.attach(fabric)``.
Core modules never import this package — they hold a duck-typed
``fabric.obs`` slot that defaults to None — so the detached hot path
allocates nothing and golden traces stay byte-identical.

See docs/observability.md for the event taxonomy and overhead
methodology.
"""

from repro.obs.export import chrome_trace, export_chrome_trace
from repro.obs.recorder import (COUNTER_NAMES, CounterSampler,
                                FlightRecorder, PROF_KEYS)
from repro.obs.trace import KINDS, TraceEvent, Tracer

__all__ = [
    "COUNTER_NAMES", "CounterSampler", "FlightRecorder", "KINDS",
    "PROF_KEYS", "TraceEvent", "Tracer", "chrome_trace",
    "export_chrome_trace",
]
