"""Structured event tracing for the scheduling fabric.

A :class:`Tracer` is a bounded ring buffer of typed
:class:`TraceEvent` records.  Timestamps are *injected* — sim modules
pass sim-time milliseconds, the daemon passes its own wall-clock
milliseconds — so this module never reads ambient time and stays clean
under schedlint's determinism pass (it is declared a sim module below).

Event kinds form a small closed taxonomy (module constants); the
Chrome-trace exporter in :mod:`repro.obs.export` pairs the
``CHUNK_START``/``CHUNK_COMPLETE``/``PREEMPT`` kinds into duration
lanes and renders everything else as instants.
"""

from __future__ import annotations

import collections
import dataclasses

SCHEDLINT_SIM = True

# -- event taxonomy ----------------------------------------------------
# submit/admission verdict (tenant + verdict/degraded_from in data)
SUBMIT = "submit"
# job placed onto a shell by Fabric._dispatch
DISPATCH = "dispatch"
# assignment handed to an executor (data: frac/restore_ms/reconfigure)
CHUNK_START = "chunk_start"
# assignment finished (data: t_start for span pairing)
CHUNK_COMPLETE = "chunk_complete"
# assignment evicted before completion (data: t_start, saved)
PREEMPT = "preempt"
# steal probe outcomes; a probe is emitted as exactly one hit or miss
# (data: victim/thief, chunks on hit, cached=True for fingerprint skips)
STEAL_HIT = "steal_hit"
STEAL_MISS = "steal_miss"
# checkpoint lifecycle
CKPT_SAVE = "ckpt_save"
CKPT_RESTORE = "ckpt_restore"
CKPT_MIGRATE = "ckpt_migrate"
# shell reconfigured to host a new module (emitted beside chunk_start)
RECONFIG = "reconfig"
# effective reserve changed on a shell (data: slots)
RESERVE = "reserve"
# one Fabric.schedule pass (data: visited shells, n_visited, n_elided)
SCHED_PASS = "sched_pass"
# realized cross-shell transfer reserved link occupancy (data: victim/
# thief, chunks, transfer_ms; only on an active link network)
TRANSFER_START = "transfer_start"
# the transfer queued behind earlier traffic before its first link
# accepted it (data adds wait_ms; emitted beside its transfer_start)
TRANSFER_QUEUED = "transfer_queued"
# the transfer's link occupancy released (sim: "net" heap event;
# daemon: wall-clock advance)
TRANSFER_COMPLETE = "transfer_complete"

KINDS = (
    SUBMIT, DISPATCH, CHUNK_START, CHUNK_COMPLETE, PREEMPT,
    STEAL_HIT, STEAL_MISS, CKPT_SAVE, CKPT_RESTORE, CKPT_MIGRATE,
    RECONFIG, RESERVE, SCHED_PASS,
    TRANSFER_START, TRANSFER_QUEUED, TRANSFER_COMPLETE,
)


@dataclasses.dataclass(slots=True)
class TraceEvent:
    """One typed trace record.

    ``t_ms`` is whatever clock the emitting layer runs on (sim time for
    the simulator, daemon wall clock for live serving); ``data`` is a
    small kind-specific dict or None.
    """

    t_ms: float
    kind: str
    shell: str | None = None
    rid: int | None = None
    chunk: int | None = None
    aid: int | None = None
    tenant: str | None = None
    data: dict | None = None


class Tracer:
    """Bounded ring buffer of :class:`TraceEvent`.

    ``events`` is a ``deque(maxlen=max_events)``: once full, the oldest
    record is evicted and ``dropped`` is incremented, so long live runs
    degrade by forgetting history rather than by growing without bound.
    """

    def __init__(self, max_events: int = 1 << 18):
        if max_events <= 0:
            raise ValueError("max_events must be positive")
        self.events: collections.deque[TraceEvent] = collections.deque(
            maxlen=max_events)
        self.dropped = 0

    def __len__(self) -> int:
        return len(self.events)

    def emit(self, t_ms: float, kind: str, shell: str | None = None,
             rid: int | None = None, chunk: int | None = None,
             aid: int | None = None, tenant: str | None = None,
             data: dict | None = None) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(TraceEvent(
            t_ms, kind, shell=shell, rid=rid, chunk=chunk, aid=aid,
            tenant=tenant, data=data))
