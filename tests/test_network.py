"""Link-level fabric network model (PR 10, core/network.py).

Three contracts under test:

1. the **link model itself** — deterministic store-and-forward
   estimates, serialization and bounded-buffer queuing on shared links,
   reserve/advance occupancy lifecycle;
2. the **compatibility shim** — a `Fabric` built with the scalar
   `transfer_ms`/per-pair knobs and one built with the equivalent
   explicit `FabricNetwork.uniform` produce byte-identical `SimResult`s
   across every field (hypothesis property);
3. the **descriptor surface** — topology JSON and `transfer_ms` keys
   are validated at `FabricDescriptor` construction/`from_json` load
   time with rich errors naming the offending pair, never later at
   steal time.
"""
from __future__ import annotations

import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from golden_traces import build_registry, _jittered_jobs, run_trace, \
    to_jsonable
from repro.core import Fabric, FabricDescriptor, FabricNetwork, \
    PolicyConfig, Registry, default_registry, simulate
from repro.obs import FlightRecorder

INF = float("inf")


def _two_switch(buffer=2, trunk_lat=1.0, trunk_bw=2.0):
    return FabricNetwork.from_topology({
        "switches": ["sw0", "sw1"],
        "ports": {"a": "sw0", "b": "sw1", "c": "sw1"},
        "default_link": {"latency_ms": 0.5, "bw_ms": 0.25, "buffer": 4},
        "links": [{"src": "sw0", "dst": "sw1", "latency_ms": trunk_lat,
                   "bw_ms": trunk_bw, "buffer": buffer}],
    }, ("a", "b", "c"))


# -- 1. link model ------------------------------------------------------------

def test_crossbar_zero_load_estimate():
    net = FabricNetwork.crossbar(("a", "b"), latency_ms=0.5,
                                 bw_ms=0.25, buffer=4)
    assert net.active
    # a->xbar + xbar->b, each latency + payload*bw
    assert net.est_transfer_ms("a", "b", 1.0, now=0.0) == \
        2 * (0.5 + 0.25)
    assert net.est_transfer_ms("a", "b", 4.0, now=0.0) == \
        2 * (0.5 + 4.0 * 0.25)
    assert net.est_transfer_ms("a", "a", 9.0, now=0.0) == 0.0


def test_shared_link_serializes_and_queues():
    net = _two_switch()
    # zero-load a->b: up(0.5+0.25) + trunk(1+2) + down(0.5+0.25) = 4.5
    free = net.est_transfer_ms("a", "b", 1.0, now=0.0)
    assert free == 4.5
    tr = net.reserve("a", "b", 1.0, now=0.0)
    assert tr.wait_ms == 0.0 and tr.total_ms == 4.5 and tr.t_done == 4.5
    # a second transfer queues behind the first on every shared link:
    # strictly slower than the free figure, and the estimate says so
    est2 = net.est_transfer_ms("a", "c", 1.0, now=0.0)
    assert est2 > free
    tr2 = net.reserve("a", "c", 1.0, now=0.0)
    assert tr2.total_ms == est2        # estimate == realized when taken
    assert tr2.wait_ms > 0.0           # blocked behind tr on a->sw0
    # the unloaded walk still reports the scalar-model belief
    assert net.est_transfer_ms("a", "c", 1.0, now=0.0,
                               loaded=False) == free


def test_bounded_buffer_backpressure_and_release():
    net = _two_switch(buffer=2)
    net.reserve("a", "b", 1.0, now=0.0)
    net.reserve("a", "c", 1.0, now=0.0)
    # trunk buffer (2) is full: bounded estimates refuse with inf...
    assert net.est_transfer_ms("a", "b", 1.0, now=0.0) == INF
    # ...but the unbounded walk (ECT dispatch) still ranks routes
    assert net.est_transfer_ms("a", "b", 1.0, now=0.0,
                               bounded=False) < INF
    v = net.version
    done = net.advance(100.0)          # both transfers long done
    assert [t.dst for t in done] == ["b", "c"]
    assert net.version > v and net.inflight == 0
    # capacity freed: estimates recover to the zero-load figure
    assert net.est_transfer_ms("a", "b", 1.0, now=100.0) == 4.5
    assert net.advance(200.0) == []    # idempotent once drained


def test_drain_releases_and_stats():
    net = _two_switch()
    t1 = net.reserve("a", "b", 2.0, now=1.0)
    assert net.drain_releases() == [t1]
    assert net.drain_releases() == []  # one-shot drain
    stats = net.stats()
    assert stats["sw0->sw1"]["transfers"] == 1
    assert stats["sw0->sw1"]["busy_ms"] > 0
    assert net.gauges() == {"links_busy": 3, "transfers_inflight": 1}


def test_uniform_shim_is_the_scalar_lookup():
    net = FabricNetwork.uniform(("a", "b"), 3.0, {("a", "b"): 7.0})
    assert not net.active and net.version == 0
    assert net.est_transfer_ms("a", "b", 99.0, now=123.0) == 7.0
    assert net.est_transfer_ms("b", "a", 99.0, now=123.0) == 3.0
    net.reserve("a", "b", 1.0, now=0.0)
    assert net.version == 0 and net.inflight == 0   # stateless


def test_network_determinism():
    """Same topology, same reserve sequence -> identical floats."""
    def run():
        net = _two_switch()
        out = []
        for i in range(6):
            out.append(net.reserve("a", "b" if i % 2 else "c",
                                   float(i + 1), now=float(i)).total_ms)
        out.extend(t.t_done for t in net.advance(50.0))
        return out
    assert run() == run()


# -- 2. topology validation at load time --------------------------------------

def test_topology_validation_errors():
    shells = ("a", "b")
    base = {"switches": ["sw"], "ports": {"a": "sw", "b": "sw"}}
    with pytest.raises(ValueError, match="no port"):
        FabricNetwork.from_topology(
            {"switches": ["sw"], "ports": {"a": "sw"}}, shells)
    with pytest.raises(ValueError, match="unknown switch 'ghost'"):
        FabricNetwork.from_topology(
            {"switches": ["sw"], "ports": {"a": "sw", "b": "ghost"}},
            shells)
    with pytest.raises(ValueError, match="unknown keys"):
        FabricNetwork.from_topology(dict(base, extra=1), shells)
    with pytest.raises(ValueError, match="'ghost'->'sw'"):
        FabricNetwork.from_topology(
            dict(base, links=[{"src": "ghost", "dst": "sw"}]), shells)
    with pytest.raises(ValueError, match="buffer must be an int >= 1"):
        FabricNetwork.from_topology(
            dict(base, default_link={"buffer": 0}), shells)
    with pytest.raises(ValueError, match="latency_ms must be"):
        FabricNetwork.from_topology(
            dict(base, links=[{"src": "a", "dst": "sw",
                               "latency_ms": -1}]), shells)
    # two switches with no trunk between them: unreachable at build
    with pytest.raises(ValueError, match="no switch path"):
        FabricNetwork.from_topology(
            {"switches": ["sw0", "sw1"],
             "ports": {"a": "sw0", "b": "sw1"}}, shells)


def test_descriptor_validates_at_load_time():
    """Satellite: malformed descriptor keys fail at from_json with a
    rich error naming the offending pair — not later at steal time."""
    with pytest.raises(ValueError, match="transfer pair 'a->ghost'"):
        FabricDescriptor.from_json(
            {"name": "f", "shells": ["a", "b"],
             "transfer_ms": {"a->ghost": 1.0}})
    with pytest.raises(ValueError, match="strings"):
        FabricDescriptor("f", ("a", "b"),
                         transfer_ms={("a", "b"): 1.0})
    with pytest.raises(ValueError, match="mutually exclusive"):
        FabricDescriptor.from_json(
            {"name": "f", "shells": ["a"],
             "transfer_ms": {"a->a": 0.0},
             "network": {"switches": ["sw"], "ports": {"a": "sw"}}})
    with pytest.raises(ValueError, match="fabric 'f'.*unknown switch"):
        FabricDescriptor.from_json(
            {"name": "f", "shells": ["a"],
             "network": {"switches": ["sw"], "ports": {"a": "nope"}}})


def test_descriptor_network_roundtrip_and_from_registry(tmp_path):
    topo = {"switches": ["sw"], "ports": {"a": "sw", "b": "sw"},
            "default_link": {"latency_ms": 0.5, "bw_ms": 0.1,
                             "buffer": 2}}
    reg = default_registry()
    from repro.core import uniform_shell
    reg.register_shell(uniform_shell("a", (2, 2), 2))
    reg.register_shell(uniform_shell("b", (2, 2), 2))
    reg.register_fabric(FabricDescriptor("linked", ("a", "b"),
                                         network=topo))
    reg.save(tmp_path)
    reg2 = Registry.load(tmp_path)
    assert reg2.fabric("linked").network == topo
    fab = Fabric.from_registry(reg2, "linked")
    assert fab.network.active
    assert fab.est_transfer_ms("a", "b") == pytest.approx(2 * (0.5 + 0.1))
    # a descriptor without a topology still loads shim fabrics
    assert not Fabric.from_registry(
        reg2, "hostpair_hetero").network.active


def test_fabric_rejects_topology_plus_pair_overrides():
    reg = build_registry()
    with pytest.raises(ValueError, match="mutually exclusive"):
        Fabric({"a": 2, "b": 2}, reg,
               network=FabricNetwork.crossbar(("a", "b")),
               transfer={"a->b": 1.0})


# -- 3. the compatibility shim, byte for byte ---------------------------------

MIX = [("u0", "batch", 4, 0, None, None),
       ("u1", "inter", 2, 2, 25.0, None),
       ("u2", "batch", 6, 0, None, None),
       ("u1", "inter", 1, 3, 12.0, None)]


@given(st.integers(0, 10**6), st.floats(0.0, 4.0), st.floats(0.0, 9.0),
       st.floats(0.0, 9.0), st.booleans())
@settings(max_examples=15, deadline=None)
def test_uniform_network_matches_scalar_byte_for_byte(
        seed, default_ms, ab, ba, ckpt):
    """Property: spelling the scalar model as an explicit uniform
    FabricNetwork changes nothing — every SimResult field identical."""
    jobs = _jittered_jobs(seed, 18, 7.0, MIX)
    pol = PolicyConfig(preemptive=True, ckpt=ckpt,
                       transfer_ms=default_ms)
    shells = {"a": (4, 1.0), "b": (2, 1.5)}
    reg1 = build_registry()
    scalar = simulate(reg1, Fabric(shells, reg1, pol,
                                   transfer={("a", "b"): ab,
                                             ("b", "a"): ba}), jobs)
    reg2 = build_registry()
    net = FabricNetwork.uniform(("a", "b"), default_ms,
                                {("a", "b"): ab, ("b", "a"): ba})
    explicit = simulate(reg2, Fabric(shells, reg2, pol, network=net),
                        jobs)
    assert to_jsonable(scalar) == to_jsonable(explicit)


# -- 4. the congested golden trace, instrumented ------------------------------

def test_congested_trace_transfer_observability():
    """The seventh golden trace realizes transfers on the trunk: starts
    and completes conserve, at least one queued behind earlier traffic,
    and the snapshot carries per-link stats."""
    rec = FlightRecorder()
    res = run_trace("congested_two_switch", obs=rec)
    snap = rec.snapshot()
    c = snap["counters"]
    assert c["transfers_started"] == c["transfers_completed"] > 0
    assert c["transfers_queued"] > 0
    assert c["transfers_started"] == c["steal_hits"]
    assert snap["network"]["sw0->sw1"]["transfers"] > 0
    assert snap["network"]["sw0->sw1"]["max_queue"] >= 2
    kinds = {e.kind for e in rec.tracer.events}
    assert {"transfer_start", "transfer_queued",
            "transfer_complete"} <= kinds
    assert res.stolen_chunks > 0 and res.ckpt_migrations > 0


def test_congestion_aware_gate_backs_off():
    """With the knob off, steal gating believes the zero-load figure:
    on a congested trunk the naive run reserves at least as many
    transfers, and realized per-chunk costs exceed its own belief."""
    def run(aware):
        reg = build_registry()
        pol = PolicyConfig(preemptive=True, congestion_aware=aware)
        net = _two_switch(buffer=2, trunk_lat=1.0, trunk_bw=8.0)
        fab = Fabric({"a": (4, 1.0), "b": (1, 1.0), "c": (1, 1.0)},
                     reg, pol, network=net)
        rec = FlightRecorder(trace=False).attach(fab)
        mix = [("t", "batch", 6, 0, None, "a")]
        simulate(reg, fab, _jittered_jobs(77, 14, 4.0, mix))
        return rec.snapshot()["counters"]
    naive, aware = run(False), run(True)
    assert naive["transfers_started"] >= aware["transfers_started"]
    assert naive["transfers_queued"] >= aware["transfers_queued"]
