"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Kernels execute their real TPU kernel body in Python on CPU via interpret
mode; tolerances account for f32-accumulation vs oracle differences and
bf16 inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.kernels.ssd_scan import ssd_scan as ssd_knl


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


def _qkv(key, b, sq, sk, hq, hkv, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, sk, hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, sk, hkv, hd), jnp.float32).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # (b, sq, sk, hq, hkv, hd, dtype, block_q, block_k)
    (1, 128, 128, 4, 4, 64, jnp.float32, 64, 64),      # MHA
    (2, 256, 256, 8, 2, 64, jnp.float32, 128, 128),    # GQA 4:1
    (1, 384, 384, 4, 1, 32, jnp.float32, 128, 128),    # MQA, non-pow2 seq
    (1, 200, 200, 4, 2, 64, jnp.float32, 64, 64),      # ragged -> padding
    (2, 128, 128, 4, 4, 128, jnp.bfloat16, 64, 64),    # bf16
    (1, 512, 512, 2, 2, 16, jnp.float32, 128, 256),    # tiny head_dim
]


@pytest.mark.parametrize(
    "b,sq,sk,hq,hkv,hd,dtype,bq,bk", FLASH_CASES)
def test_flash_attention_matches_ref(b, sq, sk, hq, hkv, hd, dtype, bq, bk):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, sq, sk, hq, hkv, hd, dtype)
    got = fa_ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                 block_k=bk, interpret=True)
    want = fa_ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


DECODE_CASES = [
    # (b, s_cache, hq, hkv, hd, length, dtype, block_k)
    (1, 512, 4, 4, 64, 512, jnp.float32, 128),
    (2, 1024, 8, 2, 64, 700, jnp.float32, 256),     # partial fill
    (1, 2048, 4, 1, 128, 1, jnp.float32, 512),      # single valid pos
    (2, 512, 4, 2, 64, 512, jnp.bfloat16, 128),
    (1, 640, 4, 4, 32, 300, jnp.float32, 128),      # ragged block count
]


@pytest.mark.parametrize("b,s,hq,hkv,hd,length,dtype,bk", DECODE_CASES)
def test_decode_attention_matches_ref(b, s, hq, hkv, hd, length, dtype, bk):
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, s, hkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, s, hkv, hd), jnp.float32).astype(dtype)
    scale = hd ** -0.5
    got = da_ops.decode_attention(q, k, v, length, scale=scale,
                                  block_k=bk, interpret=True)
    want = fa_ref.decode_attention_ref(q, k, v, length, scale=scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


SSD_CASES = [
    # (b, L, h, p, g, n, chunk, dtype)
    (1, 256, 2, 64, 1, 64, 64, jnp.float32),
    (2, 128, 4, 32, 2, 16, 32, jnp.float32),      # grouped B/C
    (1, 512, 2, 64, 1, 128, 128, jnp.float32),    # mamba2-780m-like
    (1, 128, 2, 64, 1, 16, 64, jnp.float32),      # jamba-like small state
    (1, 256, 2, 64, 1, 64, 64, jnp.bfloat16),
]


def _ssd_inputs(key, b, l, h, p, g, n, dtype):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(ks[1], (b, l, h), jnp.float32) - 1.0)
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    bb = jax.random.normal(ks[3], (b, l, g, n), jnp.float32).astype(dtype)
    cc = jax.random.normal(jax.random.fold_in(key, 9),
                           (b, l, g, n), jnp.float32).astype(dtype)
    return x, dt, a, bb, cc


@pytest.mark.parametrize("b,l,h,p,g,n,chunk,dtype", SSD_CASES)
def test_ssd_kernel_matches_ref(b, l, h, p, g, n, chunk, dtype):
    x, dt, a, bb, cc = _ssd_inputs(jax.random.PRNGKey(2), b, l, h, p, g, n,
                                   dtype)
    y_got, s_got = ssd_ops.ssd(x, dt, a, bb, cc, chunk=chunk,
                               impl="pallas_interpret")
    y_want, s_want = ssd_ref.ssd_ref(x, dt, a, bb, cc, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in half and carrying the state must equal the
    full-sequence scan (prefill -> decode continuity)."""
    b, l, h, p, g, n, chunk = 1, 256, 2, 32, 1, 32, 64
    x, dt, a, bb, cc = _ssd_inputs(jax.random.PRNGKey(3), b, l, h, p, g, n,
                                   jnp.float32)
    y_full, s_full = ssd_ref.ssd_ref(x, dt, a, bb, cc, chunk=chunk)
    half = l // 2
    y1, s1 = ssd_knl.ssd_pallas(x[:, :half], dt[:, :half], a, bb[:, :half],
                                cc[:, :half], chunk=chunk, interpret=True)
    y2, s2 = ssd_knl.ssd_pallas(x[:, half:], dt[:, half:], a, bb[:, half:],
                                cc[:, half:], chunk=chunk,
                                initial_state=s1, interpret=True)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, half:]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


def test_ssd_ref_matches_naive_recurrence():
    """Chunked oracle vs the literal per-step recurrence."""
    b, l, h, p, g, n = 1, 64, 2, 16, 1, 16
    x, dt, a, bb, cc = _ssd_inputs(jax.random.PRNGKey(4), b, l, h, p, g, n,
                                   jnp.float32)
    y_ref, s_ref = ssd_ref.ssd_ref(x, dt, a, bb, cc, chunk=16)
    rep = h // g
    bh = jnp.repeat(bb, rep, axis=2)
    ch = jnp.repeat(cc, rep, axis=2)
    s = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        decay = jnp.exp(dt[:, t] * a[None, :])               # [B,H]
        s = s * decay[..., None, None] + \
            dt[:, t][..., None, None] * x[:, t][..., :, None] * \
            bh[:, t][..., None, :]
        ys.append(jnp.einsum("bhpn,bhn->bhp", s, ch[:, t]))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_naive),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s),
                               atol=1e-4, rtol=1e-4)
