"""Golden trace corpus for the simulator core (PR 6).

Seeded, feature-complete traces whose full `SimResult` dumps were
captured from the *pre-refactor* per-event full-reschedule core and
committed as fixtures (`tests/fixtures/sim_golden_*.json`).  The
incremental event-heap core must reproduce every fixture byte for byte
— same floats, same event order, same ids — which pins the whole
scheduling contract (timeline, reserve_history, checkpoint counters,
steal accounting) across the refactor, the same discipline PRs 3-5
used for their contracts.

Arrival times are strictly increasing with seeded exponential jitter:
no two events share a timestamp, so the same-timestamp arrival
coalescing fix (PR 6 satellite) is a no-op on every golden trace and
the fixtures stay valid across it.  Same-t ordering itself is pinned
separately by the regression tests in test_simulator_core.py.

Regenerating (only when the contract changes *deliberately*):

    PYTHONPATH=src python tests/golden_traces.py

then review the fixture diff like any other contract change.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import random

from repro.core import Fabric, FabricNetwork, ImplAlt, ModuleDescriptor, \
    PolicyConfig, Registry, SimJob, simulate, uniform_shell

FIXTURE_DIR = pathlib.Path(__file__).parent / "fixtures"


def build_registry() -> Registry:
    """Modules exercising every cost-model path: a mis-estimated one
    (true_chunk_ms != est) for refine mode, footprint alternatives for
    replacement/upsizing, and a wide module that cannot fit small
    shells (dispatch feasibility)."""
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.4))))
    reg.register_module(ModuleDescriptor(
        name="wide", entrypoint="x:y",
        impls=(ImplAlt("x2", 2, 10.0),)))
    reg.register_module(ModuleDescriptor(
        name="skew", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 8.0, meta={"true_chunk_ms": 13.0}),
               ImplAlt("x2", 2, 5.0, meta={"true_chunk_ms": 8.0}))))
    return reg


def _jittered_jobs(seed: int, n: int, mean_gap_ms: float,
                   mix) -> list[SimJob]:
    """`n` jobs with strictly increasing seeded arrival times.  `mix`
    is a list of (tenant, module, chunks, priority, deadline, affinity)
    templates cycled deterministically with a seeded shuffle."""
    rng = random.Random(seed)
    t = 0.0
    jobs = []
    for i in range(n):
        t += rng.expovariate(1.0 / mean_gap_ms) + 1e-3
        ten, mod, ch, pri, dl, aff = mix[rng.randrange(len(mix))]
        jobs.append(SimJob(t, ten, mod, ch, priority=pri,
                           deadline_ms=dl, affinity=aff))
    return jobs


# -- trace definitions --------------------------------------------------------
# Each entry builds a *fresh* (registry, fabric, jobs) per call — a
# Fabric is single-use, and the equivalence tests run each trace twice.

def trace_hetero_steal_ckpt():
    """Everything at once: 3 shells with unequal speeds, priced
    transfer pairs, preemption, checkpointed migration, adaptive
    reservation, locality, an affinity pin, and deadlines."""
    reg = build_registry()
    pol = PolicyConfig(preemptive=True, ckpt=True,
                       reserve_mode="adaptive", reserve_slots_max=2,
                       transfer_ms=1.5)
    fab = Fabric({"big": (4, 1.0), "fast": (2, 2.0), "slow": (2, 0.5)},
                 reg, pol,
                 transfer={("big", "fast"): 0.5, ("slow", "big"): 3.0})
    mix = [("acme", "batch", 6, 0, None, None),
           ("acme", "batch", 4, 0, None, "big"),
           ("beta", "inter", 2, 2, 30.0, None),
           ("beta", "inter", 1, 3, 15.0, None),
           ("gama", "wide", 3, 1, None, None),
           ("gama", "batch", 5, 0, 400.0, None)]
    return reg, fab, _jittered_jobs(601, 40, 9.0, mix)


def trace_refine_hetero():
    """Online cost-model refinement on a mis-estimated module across a
    two-speed fabric: every completion moves the shared EWMA, so the
    incremental core must invalidate cached backlogs fabric-wide."""
    reg = build_registry()
    pol = PolicyConfig(preemptive=True, refine_cost_model=True,
                       transfer_ms=0.8)
    fab = Fabric({"a": (4, 1.0), "b": (4, 1.6)}, reg, pol)
    mix = [("u0", "skew", 5, 0, None, None),
           ("u1", "skew", 3, 1, None, None),
           ("u1", "inter", 2, 2, 40.0, None),
           ("u2", "batch", 4, 0, None, None)]
    return reg, fab, _jittered_jobs(602, 36, 11.0, mix)


def trace_static_reserve_preempt():
    """Homogeneous pair with a static reservation and heavy preemption
    pressure — the reserve shrink-waiver and starvation-aging paths."""
    reg = build_registry()
    pol = PolicyConfig(preemptive=True, ckpt=True, reserve_slots=1,
                       starvation_bound_ms=60.0)
    fab = Fabric({"s0": 4, "s1": 4}, reg, pol)
    mix = [("acme", "batch", 8, 0, None, None),
           ("beta", "inter", 1, 2, 12.0, None),
           ("beta", "inter", 2, 2, 25.0, None),
           ("gama", "batch", 3, 0, None, None)]
    return reg, fab, _jittered_jobs(603, 44, 6.0, mix)


def trace_single_shell_seed():
    """The degenerate seed form (bare slot count), preemptive — pins
    the single-shell fast path the daemon also drives."""
    reg = build_registry()
    pol = PolicyConfig(preemptive=True)
    mix = [("u0", "batch", 4, 0, None, None),
           ("u1", "inter", 2, 2, 20.0, None),
           ("u0", "wide", 2, 1, None, None)]
    return reg, 4, _jittered_jobs(604, 24, 14.0, mix), pol


def trace_ckpt_incapable_mix():
    """A shell without context readback in a checkpointing fabric:
    lossy eviction there, and migration onto it drops the record."""
    reg = build_registry()
    pol = PolicyConfig(preemptive=True, ckpt=True, transfer_ms=1.0,
                       reserve_mode="adaptive", reserve_slots_max=1)
    fab = Fabric({"cap": uniform_shell("cap", (2, 4), 4, speed=1.0),
                  "raw": uniform_shell("raw", (2, 2), 2, speed=1.3,
                                       ckpt=False)},
                 reg, pol)
    mix = [("acme", "batch", 7, 0, None, None),
           ("beta", "inter", 1, 2, 18.0, None),
           ("beta", "inter", 2, 3, 10.0, None),
           ("gama", "batch", 4, 0, None, None)]
    return reg, fab, _jittered_jobs(605, 38, 7.0, mix)


def trace_contracts_full():
    """SLO admission layered over everything: two contract tenants (one
    with a degraded mode, one without) sharing a preemptive,
    checkpointing, stealing, adaptively-reserving two-shell fabric with
    background batch tenants offering ~2x capacity — the trace must
    exercise ADMIT, DEGRADE, and REJECT verdicts (asserted by the
    feature-coverage test)."""
    from repro.core import QoSContract
    reg = build_registry()
    # "lite" is the degraded tier of beta's heavy "batch" jobs: same
    # interface, a fraction of the service time
    reg.register_module(ModuleDescriptor(
        name="lite", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 1.5),)))
    pol = PolicyConfig(preemptive=True, ckpt=True, transfer_ms=1.0,
                       reserve_mode="adaptive", reserve_slots_max=1)
    fab = Fabric({"s0": (4, 1.0), "s1": (4, 1.3)}, reg, pol)
    fab.register_contract(QoSContract(
        "beta", rate_per_s=40.0, deadline_ms=220.0, degraded="lite"))
    fab.register_contract(QoSContract(
        "dash", rate_per_s=15.0, deadline_ms=480.0))
    mix = [("acme", "batch", 4, 0, None, None),
           ("acme", "batch", 2, 0, None, None),
           ("beta", "batch", 2, 2, None, None),
           ("beta", "inter", 1, 3, 15.0, None),
           ("dash", "inter", 3, 2, None, None),
           ("gama", "batch", 3, 0, None, None)]
    return reg, fab, _jittered_jobs(606, 48, 5.0, mix)


def trace_congested_two_switch():
    """Link-level interconnect (PR 10): a two-switch topology with a
    thin trunk between them.  Heavy batch work is pinned to the east
    shell, so the two west shells steal across the shared trunk —
    concurrent transfers serialize and queue there (bounded buffer),
    steal gating reads load-aware estimates, and preemption +
    checkpointed migration run over the same priced routes."""
    reg = build_registry()
    pol = PolicyConfig(preemptive=True, ckpt=True,
                       reserve_mode="adaptive", reserve_slots_max=1)
    topo = {
        "switches": ["sw0", "sw1"],
        "ports": {"east": "sw0", "west0": "sw1", "west1": "sw1"},
        "default_link": {"latency_ms": 0.3, "bw_ms": 0.2, "buffer": 3},
        "links": [{"src": "sw0", "dst": "sw1",
                   "latency_ms": 0.8, "bw_ms": 1.2, "buffer": 2}],
    }
    net = FabricNetwork.from_topology(topo, ("east", "west0", "west1"))
    fab = Fabric({"east": (4, 1.0), "west0": (2, 1.4),
                  "west1": (2, 0.9)}, reg, pol, network=net)
    mix = [("acme", "batch", 6, 0, None, "east"),
           ("acme", "batch", 5, 0, None, "east"),
           ("beta", "inter", 2, 2, 30.0, "east"),
           ("beta", "inter", 1, 3, 15.0, "east"),
           ("gama", "batch", 4, 0, 500.0, None)]
    return reg, fab, _jittered_jobs(620, 40, 8.0, mix)


TRACES = {
    "hetero_steal_ckpt": trace_hetero_steal_ckpt,
    "refine_hetero": trace_refine_hetero,
    "static_reserve_preempt": trace_static_reserve_preempt,
    "single_shell_seed": trace_single_shell_seed,
    "ckpt_incapable_mix": trace_ckpt_incapable_mix,
    "contracts_full": trace_contracts_full,
    "congested_two_switch": trace_congested_two_switch,
}


def run_trace(name: str, obs=None):
    """Build the trace fresh and simulate it; returns the SimResult.

    `obs`: an optional `repro.obs.FlightRecorder` to attach before
    simulating — the byte-identity suite uses it to pin down that an
    attached recorder never changes scheduling outputs."""
    built = TRACES[name]()
    if len(built) == 4:                   # bare-slot-count seed form
        reg, spec, jobs, pol = built
        if obs is None:
            return simulate(reg, spec, jobs, pol)
        fab = Fabric({"shell0": spec}, reg, pol)   # _as_fabric's shape
        obs.attach(fab)
        return simulate(reg, fab, jobs)
    reg, fab, jobs = built
    if obs is not None:
        obs.attach(fab)
    return simulate(reg, fab, jobs)


def to_jsonable(res) -> dict:
    """Full SimResult as JSON-safe data.  Dict keys become strings and
    tuples become lists (JSON has neither), so int-keyed maps are
    dumped as sorted [key, value] pairs; floats survive a json
    round-trip exactly (shortest-repr encoding), which is what makes
    fixture comparison byte-for-byte on every metric."""
    d = dataclasses.asdict(res)
    d["request_latency"] = sorted(d["request_latency"].items())
    d["request_meta"] = sorted(d["request_meta"].items())
    if not d["slo"]:
        # contracts off: serialise exactly the pre-SLO shape, so the
        # PR 6 fixtures (and any future no-contract fixture) stay valid
        d.pop("slo")
    if not d["metrics"]:
        # likewise: no flight recorder attached (repro.obs) means the
        # pre-observability serialisation, byte-for-byte
        d.pop("metrics")
    return json.loads(json.dumps(d, sort_keys=True))


def load_fixture(name: str) -> dict:
    with open(FIXTURE_DIR / f"sim_golden_{name}.json") as f:
        return json.load(f)


def main() -> None:
    FIXTURE_DIR.mkdir(exist_ok=True)
    for name in TRACES:
        res = run_trace(name)
        path = FIXTURE_DIR / f"sim_golden_{name}.json"
        with open(path, "w") as f:
            json.dump(to_jsonable(res), f, sort_keys=True, indent=1)
            f.write("\n")
        print(f"{path}: makespan={res.makespan:.3f} "
              f"preemptions={res.preemptions} stolen={res.stolen_chunks} "
              f"saves={res.ckpt_saves} restores={res.ckpt_restores} "
              f"migrations={res.ckpt_migrations}")


if __name__ == "__main__":
    main()
