"""Checkpoint/restore subsystem (core/checkpoint.py) + admission reservation.

Contract under test:
  - with `ckpt=False` (the default) the scheduler/simulator traces are
    byte-identical to the pre-checkpoint (PR 3) contract — pinned both
    by a property test over policy spellings and by golden values
    captured from the PR 3 code on a deterministic trace;
  - with `ckpt=True` an evicted chunk's progress survives: the resumed
    run covers only the remaining fraction plus the priced restore
    cost, the preemptor realizes the victims' save cost (net of its
    reconfiguration overlap), and `SimResult.reclaimed_ms` /
    `discarded_ms` split the evicted slot-time exactly;
  - every chunk still completes exactly once under mixed preemption +
    checkpointing + cross-shell migration at mixed speeds (property);
  - checkpointed chunks migrate across shells only through the *gated*
    resume-steal (restore + transfer + remaining must beat the victim
    draining locally), never via an unpriced tail steal;
  - shells with `ShellSpec.ckpt = False` neither save nor accept
    checkpoints (and the flag survives the JSON roundtrip);
  - `PolicyConfig.reserve_slots` holds back aligned slots for the
    interactive class, with an unplaceable-forever waiver;
  - the live daemon mirrors the contract on its wall-clock path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
from collections import Counter

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import CheckpointManager, Daemon, Fabric, ImplAlt, \
    ModuleDescriptor, PolicyConfig, Registry, Shell, SimJob, \
    default_registry, simulate, uniform_shell
from repro.core.registry import Registry as _Registry
from repro.core.scheduler import Assignment, SchedulerState
from repro.core.shell import ShellSpec


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.4))))
    return reg


def _check_spans_consistent(res, n_slots: int) -> None:
    """Capacity + no double-booking over completed AND evicted spans."""
    spans = list(res.timeline) + list(res.preempted_spans)
    events = []
    for t0, t1, (s, size), _ in spans:
        events += [(t0, size), (t1, -size)]
    busy = 0
    for _, d in sorted(events, key=lambda e: (e[0], e[1])):
        busy += d
        assert busy <= n_slots
    per_slot: dict[int, list] = {}
    for t0, t1, (s, size), _ in spans:
        for i in range(s, s + size):
            per_slot.setdefault(i, []).append((t0, t1))
    for slot_spans in per_slot.values():
        slot_spans.sort()
        for (a0, a1), (b0, b1) in zip(slot_spans, slot_spans[1:]):
            assert b0 >= a1 - 1e-9, "slot double-booked"


# -- manager unit behavior ----------------------------------------------------

def test_manager_costs_meta_overrides_and_speed_scaling():
    reg = _registry()
    reg.register_module(ModuleDescriptor(
        name="heavy", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 30.0,
                       meta={"ckpt_save_ms": 4.0,
                             "ckpt_restore_ms": 6.0}),)))
    mgr = CheckpointManager(reg, PolicyConfig(ckpt=True))
    # policy defaults for a module without overrides
    assert mgr.save_cost_ms("batch", 1) == 1.0
    assert mgr.restore_cost_ms("batch", 1) == 1.0
    # per-implementation overrides, speed-scaled like chunk times
    assert mgr.save_cost_ms("heavy", 1) == 4.0
    assert mgr.restore_cost_ms("heavy", 1) == 6.0
    assert mgr.save_cost_ms("heavy", 1, speed=2.0) == 2.0
    assert mgr.restore_cost_ms("heavy", 1, speed=0.5) == 12.0


def test_manager_save_take_rekey_drop():
    from repro.core.allocator import Range
    reg = _registry()
    mgr = CheckpointManager(reg, PolicyConfig(ckpt=True))
    a = Assignment(7, 2, "batch", 1, Range(0, 1), True, aid=0,
                   t_start=0.0)
    # evicted at t=25 after a 5 ms reconfiguration: 20/40 of the work done
    cost = mgr.save(a, 25.0, est_full_ms=40.0, shell="s0")
    assert cost == 1.0 and len(mgr) == 1
    rec = mgr.peek(7, 2)
    assert rec.progress == 0.5 and rec.shell == "s0"
    assert mgr.pending_progress(7) == 0.5
    # a second eviction of the resumed run accumulates progress on top
    a2 = Assignment(7, 2, "batch", 1, Range(0, 1), False, aid=1,
                    t_start=30.0, frac=0.5, restore_ms=1.0)
    mgr.take(7, 2)
    assert mgr.save(a2, 41.0, est_full_ms=40.0, shell="s0") == 1.0
    assert mgr.peek(7, 2).progress == 0.75          # 0.5 + 10/40
    assert mgr.stats["saves"] == 2
    # an eviction inside the overhead window re-records prior progress
    # without paying a new save (the old context is still on file)
    a3 = Assignment(7, 2, "batch", 1, Range(0, 1), True, aid=2,
                    t_start=50.0, frac=0.25, restore_ms=1.0)
    mgr.take(7, 2)
    assert mgr.save(a3, 53.0, est_full_ms=40.0) == 0.0   # 3 < 5+1 overhead
    assert mgr.peek(7, 2).progress == 0.75
    assert mgr.stats["saves"] == 2
    # migration re-keys; an incapable thief drops the record instead
    assert mgr.rekey((7, 2), (9, 0), shell="s1")
    assert mgr.peek(9, 0).shell == "s1" and mgr.peek(7, 2) is None
    assert mgr.stats["migrations"] == 1
    assert not mgr.rekey((9, 0), (11, 0), shell="s2", capable=False)
    assert len(mgr) == 0 and mgr.stats["dropped"] == 1
    # zero-progress evictions never create a record
    a4 = Assignment(8, 0, "batch", 1, Range(0, 1), True, aid=3,
                    t_start=0.0)
    assert mgr.save(a4, 2.0, est_full_ms=40.0) == 0.0
    assert len(mgr) == 0
    # drop_request releases an aborted request's records
    mgr.save(a, 25.0, est_full_ms=40.0)
    mgr.drop_request(7)
    assert len(mgr) == 0


# -- off-path byte-identity (the PR 3 contract) -------------------------------

offpath_jobs_strategy = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, "a", "b"])),
    min_size=1, max_size=15)


@given(offpath_jobs_strategy,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_ckpt_off_is_byte_identical(raw, sizes, preemptive):
    """`ckpt=False` — spelled implicitly, or explicitly with zeroed
    save/restore costs and zero reservation — reproduces the PR 3
    scheduler/simulator trace byte-for-byte on every SimResult field.
    The predictive-reservation knobs are inert on the static path:
    `reserve_slots_max`/`arrival_alpha` only matter under
    `reserve_mode="adaptive"`."""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    shells = {"a": sizes[0], "b": sizes[1]}
    base = simulate(_registry(), shells, jobs,
                    PolicyConfig(preemptive=preemptive, steal=True))
    explicit = simulate(_registry(), shells, jobs,
                        PolicyConfig(preemptive=preemptive, steal=True,
                                     ckpt=False, ckpt_save_ms=0.0,
                                     ckpt_restore_ms=0.0,
                                     reserve_slots=0,
                                     reserve_mode="static",
                                     reserve_slots_max=7,
                                     arrival_alpha=0.9))
    assert dataclasses.asdict(base) == dataclasses.asdict(explicit)
    # the new counters are inert on the off path
    assert base.discarded_ms == base.wasted_time
    assert base.reclaimed_ms == 0.0
    assert base.ckpt_saves == base.ckpt_restores == 0
    assert base.ckpt_migrations == 0


def _golden_jobs() -> list[SimJob]:
    rng = random.Random(42)
    jobs = []
    t = 0.0
    for i in range(8):
        jobs.append(SimJob(t, f"b{i % 2}", "batch", rng.randint(2, 5)))
        t += rng.uniform(5.0, 30.0)
    t = 3.0
    for i in range(12):
        jobs.append(SimJob(t, "hi", "inter", 1, priority=3,
                           deadline_ms=25.0))
        t += rng.uniform(6.0, 18.0)
    return jobs


@pytest.mark.parametrize("shells,golden", [
    (4, (299.8586027605912, 65.20653662341455, 12, 29, 0,
         "b045278dad64bc86")),
    ({"a": 2, "b": 1}, (383.6578408109875, 80.6578408109875, 9, 20, 3,
                        "f7027581c079e2e7")),
    ({"a": (2, 1.0), "b": (2, 0.5)},
     (390.0711882065109, 159.69746151299523, 12, 27, 4,
      "fb3015baae669bb1")),
])
def test_ckpt_off_matches_pr3_goldens(shells, golden):
    """Regression anchor: values captured by running the PR 3 code on
    this exact trace — the off path must keep producing them."""
    res = simulate(_registry(), shells, _golden_jobs(),
                   PolicyConfig(preemptive=True, steal=True,
                                transfer_ms=1.0 if isinstance(shells,
                                                              dict)
                                else 0.0))
    h = hashlib.sha256(
        repr((res.timeline, res.preempted_spans)).encode()) \
        .hexdigest()[:16]
    assert (res.makespan, res.wasted_time, res.preemptions,
            res.reconfigurations, res.stolen_chunks, h) == golden


# -- resume semantics ---------------------------------------------------------

def test_resumed_chunk_runs_only_remaining_fraction():
    """Single slot: a 40 ms chunk evicted 5 ms into its compute (10 ms
    wall minus its 5 ms reconfiguration) resumes for the remaining
    35 ms plus the 1 ms restore — 4 ms sooner than the lossy rerun;
    the save (1 ms) hides under the preemptor's reconfiguration, so
    the high-priority latency is identical."""
    jobs = [SimJob(0.0, "lo", "batch", 1),
            SimJob(10.0, "hi", "inter", 1, priority=5)]
    off = simulate(_registry(), 1, jobs, PolicyConfig(preemptive=True))
    on = simulate(_registry(), 1, jobs,
                  PolicyConfig(preemptive=True, ckpt=True))
    assert off.makespan == 64.0     # 10 evict + (5+4) hi + (5+40) rerun
    assert on.makespan == 60.0      # 10 evict + (5+4) hi + (5+1+35)
    hi_off = next(r for r, m in off.request_meta.items()
                  if m["priority"] == 5)
    hi_on = next(r for r, m in on.request_meta.items()
                 if m["priority"] == 5)
    assert on.request_latency[hi_on] == off.request_latency[hi_off]
    assert on.ckpt_saves == 1 and on.ckpt_restores == 1
    # the evicted 10 ms span splits: 5 ms compute reclaimed, the 5 ms
    # reconfiguration overhead discarded
    assert on.wasted_time == 10.0
    assert on.reclaimed_ms == 5.0 and on.discarded_ms == 5.0
    assert off.discarded_ms == 10.0 and off.reclaimed_ms == 0.0


def test_save_cost_beyond_reconfig_overlap_delays_preemptor():
    """A context save longer than the reconfiguration penalty delays
    the preemptor by exactly the excess."""
    jobs = [SimJob(0.0, "lo", "batch", 1),
            SimJob(10.0, "hi", "inter", 1, priority=5)]
    on = simulate(_registry(), 1, jobs,
                  PolicyConfig(preemptive=True, ckpt=True,
                               ckpt_save_ms=8.0))
    hi = next(r for r, m in on.request_meta.items()
              if m["priority"] == 5)
    # hi pays reconfig 5 + excess save (8 - 5) + 4 ms compute
    assert on.request_latency[hi] == 12.0


def test_zero_progress_eviction_saves_nothing():
    """A chunk evicted inside its own reconfiguration window has no
    progress: no record, no save cost, no restore on the rerun."""
    jobs = [SimJob(0.0, "lo", "batch", 1),
            SimJob(3.0, "hi", "inter", 1, priority=5)]
    off = simulate(_registry(), 1, jobs, PolicyConfig(preemptive=True))
    on = simulate(_registry(), 1, jobs,
                  PolicyConfig(preemptive=True, ckpt=True))
    assert on.makespan == off.makespan == 57.0
    assert on.ckpt_saves == 0 and on.ckpt_restores == 0
    assert on.reclaimed_ms == 0.0


def test_refinement_unbiased_by_resumed_fractions():
    """A resumed chunk's observation is scaled back to a full chunk:
    with est == true the estimate must stay exact through a
    preempt/resume cycle."""
    reg = _registry()
    fab = Fabric({"s": 1}, reg,
                 PolicyConfig(preemptive=True, ckpt=True,
                              refine_cost_model=True))
    res = simulate(reg, fab, [SimJob(0.0, "lo", "batch", 1),
                              SimJob(10.0, "hi", "inter", 1, priority=5)])
    assert res.ckpt_restores == 1
    assert fab.cost.est_chunk_ms("batch", 1) == 40.0
    assert fab.cost.est_chunk_ms("inter", 1) == 4.0


# -- exactly-once under mixed preemption + migration (property) ---------------

mixed_jobs_strategy = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, "a", "b"])),
    min_size=1, max_size=15)


@given(mixed_jobs_strategy,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]),
       st.sampled_from([(1.0, 1.0), (0.5, 2.0), (1.0, 0.25)]),
       st.sampled_from([0.0, 1.0]))
@settings(max_examples=60, deadline=None)
def test_exactly_once_under_ckpt_and_migration(raw, sizes, speeds,
                                               transfer):
    """Preemption + checkpointing + stealing + affinity over shells of
    mixed speeds: every chunk completes exactly once, capacity holds
    over completed and evicted spans, the discarded/reclaimed split is
    exact, and no checkpoint record leaks."""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    fab = Fabric({"a": (sizes[0], speeds[0]), "b": (sizes[1], speeds[1])},
                 _registry(),
                 PolicyConfig(preemptive=True, steal=True, ckpt=True,
                              transfer_ms=transfer))
    res = simulate(_registry(), fab, jobs)
    done = Counter(rid for *_, rid in res.timeline)
    for rid, meta in res.request_meta.items():
        assert done[rid] == meta["n_chunks"], \
            f"rid {rid}: {done[rid]} completions != {meta['n_chunks']}"
    assert res.preemptions == len(res.preempted_spans)
    _check_spans_consistent(res, sum(sizes))
    assert abs(res.discarded_ms + res.reclaimed_ms
               - res.wasted_time) < 1e-6
    assert res.reclaimed_ms >= 0.0 and res.discarded_ms >= -1e-9
    assert len(fab.ckpt) == 0, "leaked checkpoint records"


# -- checkpointed migration ---------------------------------------------------

def test_checkpointed_chunk_migrates_when_move_wins():
    """An idle shell resumes another shell's checkpointed victim when
    restore + transfer + remaining beats the victim draining locally —
    and the resumed run on the thief is priced exactly."""
    jobs = [SimJob(0.0, "lo", "batch", 1, affinity="v"),
            SimJob(10.0, "hi", "inter", 1, priority=5, affinity="v")]
    fab = Fabric({"v": 1, "t": 1}, _registry(),
                 PolicyConfig(preemptive=True, ckpt=True, steal=True))
    res = simulate(_registry(), fab, jobs)
    # evicted at 10 with 5 ms of compute done (0.125 of 40): the thief
    # pays reconfig 5 + restore 1 + remaining 35 from t=10 -> 51
    assert res.ckpt_migrations == 1 and res.stolen_chunks == 1
    assert res.makespan == 51.0
    assert res.per_shell["t"]["busy_ms"] == 41.0


def test_checkpointed_migration_skipped_when_move_loses():
    """A prohibitive transfer cost keeps the checkpointed chunk home —
    it resumes on its origin shell after the preemptor; an unpriced
    tail steal must never move it."""
    jobs = [SimJob(0.0, "lo", "batch", 1, affinity="v"),
            SimJob(10.0, "hi", "inter", 1, priority=5, affinity="v")]
    fab = Fabric({"v": 1, "t": 1}, _registry(),
                 PolicyConfig(preemptive=True, ckpt=True, steal=True,
                              transfer_ms=1000.0))
    res = simulate(_registry(), fab, jobs)
    assert res.ckpt_migrations == 0 and res.stolen_chunks == 0
    assert res.makespan == 60.0         # local resume: 19 + 5 + 1 + 35
    assert res.per_shell["t"]["busy_ms"] == 0.0


def test_pristine_tail_still_steals_around_checkpointed_front():
    """Tail stealing keeps working with checkpointing on: pristine
    chunks move ungated while the checkpointed front chunk stays gated."""
    jobs = [SimJob(0.0, "lo", "batch", 4, affinity="v"),
            SimJob(10.0, "hi", "inter", 1, priority=5, affinity="v")]
    fab = Fabric({"v": 1, "t": 1}, _registry(),
                 PolicyConfig(preemptive=True, ckpt=True, steal=True))
    res = simulate(_registry(), fab, jobs)
    done = Counter(rid for *_, rid in res.timeline)
    for rid, meta in res.request_meta.items():
        assert done[rid] == meta["n_chunks"]
    assert res.stolen_chunks > 0
    assert len(fab.ckpt) == 0


def test_stolen_chunk_evicted_mid_transfer_records_no_phantom_progress():
    """Regression: a freshly-stolen chunk's transfer time is overhead,
    not compute.  Evicted before the transfer+reconfig window ends, it
    has zero progress — no record, no save, and the rerun covers the
    full chunk (the checkpoint must not silently swallow the 10 ms the
    chunk never actually computed)."""
    jobs = [SimJob(0.0, "lo", "batch", 2, affinity="v"),
            SimJob(12.0, "hi", "inter", 1, priority=5, affinity="t")]
    fab = Fabric({"v": 1, "t": 1}, _registry(),
                 PolicyConfig(preemptive=True, ckpt=True, steal=True,
                              transfer_ms=10.0))
    res = simulate(_registry(), fab, jobs)
    # chunk1 stolen onto t at t=0 (transfer 10 + reconfig 5), evicted
    # at t=12 inside that overhead window: no checkpoint
    assert res.stolen_chunks == 1
    assert res.ckpt_saves == 0 and res.ckpt_restores == 0
    assert res.reclaimed_ms == 0.0 and res.discarded_ms == 12.0
    # full rerun after hi (done 21): reconfig 5 + 40, transfer not
    # re-paid -> 66; a phantom checkpoint would finish at 60 having
    # run 7 ms short
    assert res.makespan == 66.0


# -- per-shell capability -----------------------------------------------------

def test_ckpt_incapable_shell_evicts_lossily():
    """A `ShellSpec.ckpt = False` shell discards evicted work even when
    the policy checkpoints — identical to the off-path trace."""
    spec = uniform_shell("noc", (1, 1), 1, ckpt=False)
    jobs = [SimJob(0.0, "lo", "batch", 1),
            SimJob(10.0, "hi", "inter", 1, priority=5)]
    on = simulate(_registry(), {"noc": spec}, jobs,
                  PolicyConfig(preemptive=True, ckpt=True))
    assert on.makespan == 64.0          # the lossy rerun, not 60.0
    assert on.ckpt_saves == 0 and on.reclaimed_ms == 0.0
    assert on.discarded_ms == on.wasted_time == 10.0


def test_shellspec_ckpt_flag_json_roundtrip(tmp_path):
    reg = default_registry()
    reg.register_shell(uniform_shell("noc", (1, 2), 2, ckpt=False))
    reg.save(tmp_path)
    reg2 = _Registry.load(tmp_path)
    assert reg2.shell("noc").ckpt is False
    assert reg2.shell("host8_s4").ckpt is True
    # pre-checkpoint saves (no "ckpt" key) default to capable
    assert ShellSpec.from_json(
        {"name": "old", "grid": [1, 1], "regions": []}).ckpt is True


# -- admission reservation (steal-aware admission) ----------------------------

def test_reserve_slots_holds_capacity_for_interactive_class():
    """With the last slot reserved, batch replication stops at 3 of 4
    slots and a cooperative (non-preemptive) policy still serves the
    interactive arrival immediately; without the reservation it waits
    out a full batch chunk."""
    jobs = [SimJob(0.0, "b", "batch", 4),
            SimJob(5.0, "live", "inter", 1, priority=3)]
    plain = simulate(_registry(), 4, jobs, PolicyConfig(preemptive=False))
    res = simulate(_registry(), 4, jobs,
                   PolicyConfig(preemptive=False, reserve_slots=1))
    hi = next(r for r, m in res.request_meta.items()
              if m["priority"] == 3)
    assert res.request_latency[hi] == 9.0       # reconfig 5 + 4, no wait
    assert plain.request_latency[hi] > 30.0     # behind a 40 ms chunk
    # batch placements never touch the reserved slot
    for t0, t1, (s, size), rid in res.timeline:
        if res.request_meta[rid]["priority"] == 0:
            assert s + size <= 3, "batch placed into the reserved slot"
    assert res.preemptions == 0


def test_reserve_waived_when_module_would_be_unplaceable():
    """A reservation that would leave a module with no feasible window
    is waived for that request instead of wedging it forever."""
    reg = _registry()
    reg.register_module(ModuleDescriptor(
        name="wide", entrypoint="x:y",
        impls=(ImplAlt("x2", 2, 10.0),)))
    res = simulate(reg, 2, [SimJob(0.0, "b", "wide", 1)],
                   PolicyConfig(reserve_slots=1))
    assert res.makespan == 15.0                 # placed despite reserve
    res2 = simulate(reg, 4, [SimJob(0.0, "b", "wide", 1)],
                    PolicyConfig(reserve_slots=1))
    (t0, t1, (s, size), _), = res2.timeline
    assert s + size <= 3                        # feasible -> honored


def test_reserve_shields_reserved_window_from_low_priority_preemptor():
    """An aged low-priority request must not preempt into the reserved
    window: the reservation holds against placement AND eviction."""
    state = SchedulerState(2, _registry(),
                           PolicyConfig(preemptive=True, reserve_slots=1,
                                        starvation_bound_ms=1e9))
    hi = state.submit("live", "inter", 2, now=0.0, priority=3)
    issued = state.schedule(now=0.0)
    assert len(issued) == 2                     # both slots, incl. reserve
    lo = state.submit("b", "batch", 1, now=1.0, priority=0)
    state.schedule(now=1.0)
    assert not state.drain_preempted()
    assert lo.pending == 1                      # waits; nothing evicted


# -- live daemon --------------------------------------------------------------

def test_daemon_scheduler_core_checkpoints_on_wall_clock():
    """Drive the daemon's scheduler state deterministically: an evicted
    chunk records wall-clock progress and resumes at its remaining
    fraction with the restore cost priced in."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg,
               PolicyConfig(preemptive=True, ckpt=True))
    try:
        with d._lock:
            st = d.state
            req = st.submit("lo", "mandelbrot", 1,
                            payloads=[object()], now=0.0)
            (a0,) = st.schedule(now=0.0)
            assert a0.frac == 1.0 and a0.reconfigure
            # eviction at t=9: 4 ms of compute behind the 5 ms reconfig
            st.submit("hi", "sobel", 1, payloads=[object()], now=9.0,
                      priority=5)
            placed = st.schedule(now=9.0)
            (victim,) = st.drain_preempted()
            assert victim.aid == a0.aid
            rec = d.fabric.ckpt.peek(req.rid, victim.chunk)
            assert rec is not None
            assert rec.progress == pytest.approx(4.0 / 12.0)
            assert d.ckpt_stats["saves"] == 1
            # the preemptor's save cost hid under its reconfiguration
            assert all(a.save_ms == 0.0 for a in placed)
            # complete the preemptor; the victim resumes at remainder
            (hi_a,) = placed
            assert st.complete(hi_a, now=15.0)
            (resumed,) = st.schedule(now=15.0)
            assert resumed.rid == req.rid
            assert resumed.frac == pytest.approx(1.0 - 4.0 / 12.0)
            assert resumed.restore_ms == 1.0
            assert d.ckpt_stats["restores"] == 1
            assert len(d.fabric.ckpt) == 0
            assert st.complete(resumed, now=25.0)
    finally:
        d.shutdown()


def test_daemon_consistent_under_preemptive_ckpt_policy():
    """Live end-to-end: a preemptive+ckpt policy keeps futures, results
    and allocator consistent — every chunk resolves exactly once even
    when resumed chunks re-run in full in-process."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg,
               PolicyConfig(preemptive=True, ckpt=True,
                            reconfig_penalty_ms=0.1))
    try:
        rng = np.random.default_rng(0)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        img = rng.random((1024, 1024)).astype(np.float32)
        lo = d.submit("lo", "mandelbrot", [(re, im)] * 3, priority=0)
        hi = d.submit("hi", "sobel", [(img,)], priority=5,
                      deadline_ms=50.0)
        assert len(lo.future.result(timeout=300)) == 3
        assert len(hi.future.result(timeout=300)) == 1
        with d._lock:
            assert not d._results and not d._handles
            assert not d.state.alloc.busy and not d.state.active
            assert isinstance(d.ckpt_stats, dict)
            assert len(d.fabric.ckpt) == 0
        assert d.stats["chunks"] == 4
    finally:
        d.shutdown()
