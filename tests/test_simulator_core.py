"""PR 6: the incremental event-heap simulator core.

Four pillars, mirroring the refactor's risk surface:

1. **Golden byte-identity** — every committed fixture
   (tests/fixtures/sim_golden_*.json, captured from the pre-refactor
   full-reschedule core) must be reproduced byte for byte by the
   current core.  This pins the entire `SimResult` contract: timeline,
   reserve_history, checkpoint counters, steal accounting, float for
   float.

2. **Old-vs-new equivalence** — `Fabric.full_reschedule = True`
   restores the pre-PR 6 control flow (every shell reschedules on
   every pass).  Random feature-mixed traces must produce identical
   results in both modes: the dirty-shell set is a pure control-flow
   elision.

3. **Same-timestamp arrival coalescing** — the one deliberate behavior
   change.  All jobs arriving at the same instant are admitted before
   placement runs; previously the first same-t job could upsize into
   capacity its simultaneous peers needed (an ordering bug — no event
   separates the arrivals).

4. **Bookkeeping under preempt+steal+ckpt interleavings** — the O(1)
   pending counter must track its defining recomputation through every
   mutation path, the allocator bitmask must mirror the busy set, and
   the stale-event heap compaction must be event-order-invisible.
"""
from __future__ import annotations

import dataclasses
import random

import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from golden_traces import TRACES, build_registry, load_fixture, \
    run_trace, to_jsonable
from repro.core import Fabric, ImplAlt, ModuleDescriptor, PolicyConfig, \
    Registry, SimJob, simulate
import repro.core.simulator as simulator_mod


# -- 1. golden byte-identity --------------------------------------------------

@pytest.mark.parametrize("name", sorted(TRACES))
def test_golden_trace_byte_identity(name):
    """The incremental core reproduces the pre-refactor fixture dump
    exactly — every float, every event, every counter."""
    assert to_jsonable(run_trace(name)) == load_fixture(name)


def test_golden_traces_have_feature_coverage():
    """The corpus would silently stop pinning what it claims to pin if
    a trace drifted below its feature thresholds."""
    res = {name: run_trace(name) for name in TRACES}
    assert res["hetero_steal_ckpt"].stolen_chunks > 0
    assert res["hetero_steal_ckpt"].ckpt_restores > 0
    assert res["hetero_steal_ckpt"].preemptions > 0
    assert any(res["hetero_steal_ckpt"].reserve_history.values())
    assert res["refine_hetero"].preemptions > 0
    assert res["static_reserve_preempt"].preemptions > 10
    assert res["ckpt_incapable_mix"].discarded_ms > 0
    assert res["single_shell_seed"].preemptions > 0
    # the admission trace must exercise every verdict kind, on top of
    # stealing + checkpointing + the adaptive reservation
    slo = res["contracts_full"].slo
    assert sum(e["degraded"] for e in slo.values()) > 0
    assert sum(e["rejected"] for e in slo.values()) > 0
    assert sum(e["admitted"] for e in slo.values()) > 0
    assert any(e["contract"] and e["attainment"] is not None
               for e in slo.values())
    assert res["contracts_full"].stolen_chunks > 0
    assert res["contracts_full"].ckpt_saves > 0
    # the interconnect trace must steal across the congested trunk AND
    # migrate checkpoints over it (transfer queuing itself is asserted
    # with a recorder attached, in test_network.py)
    assert res["congested_two_switch"].stolen_chunks > 10
    assert res["congested_two_switch"].ckpt_migrations > 0
    assert res["congested_two_switch"].preemptions > 0


# -- 2. old-vs-new equivalence ------------------------------------------------

def _rand_trace(seed: int, n_jobs: int) -> list[SimJob]:
    rng = random.Random(seed)
    jobs, t = [], 0.0
    for _ in range(n_jobs):
        t += rng.expovariate(0.25) + 1e-3
        u = rng.random()
        if u < 0.45:
            jobs.append(SimJob(t, f"t{rng.randrange(4)}", "batch",
                               rng.randint(2, 6)))
        elif u < 0.8:
            jobs.append(SimJob(t, f"t{rng.randrange(4)}", "inter",
                               rng.randint(1, 3), priority=2,
                               deadline_ms=25.0))
        else:
            jobs.append(SimJob(t, f"t{rng.randrange(4)}", "wide",
                               rng.randint(1, 4), priority=1))
    return jobs


def _run_both(shells, jobs, pol, transfer=None):
    """The same trace through the incremental and the full-reschedule
    core; returns both canonicalized result dumps."""
    out = []
    for full in (False, True):
        reg = build_registry()
        fab = Fabric(dict(shells), reg, pol, transfer=transfer)
        fab.full_reschedule = full
        out.append(to_jsonable(simulate(reg, fab, jobs)))
    return out


@given(st.integers(0, 10**6), st.integers(8, 22), st.booleans(),
       st.booleans(), st.sampled_from(["static", "adaptive"]))
@settings(max_examples=25, deadline=None)
def test_incremental_equals_full_reschedule(seed, n_jobs, ckpt, steal,
                                            mode):
    """Property: on random feature-mixed heterogeneous traces the
    dirty-shell core and the everything-every-pass core are
    byte-identical."""
    pol = PolicyConfig(preemptive=True, ckpt=ckpt, steal=steal,
                       reserve_mode=mode, reserve_slots_max=2,
                       reserve_slots=1 if mode == "static" else 0,
                       transfer_ms=0.7, starvation_bound_ms=50.0)
    inc, full = _run_both({"a": (4, 1.0), "b": (2, 1.7), "c": (2, 0.6)},
                          _rand_trace(seed, n_jobs), pol)
    assert inc == full


@given(st.integers(0, 10**6))
@settings(max_examples=10, deadline=None)
def test_equivalence_with_refinement(seed):
    """Cost-model refinement moves the shared EWMA on every completion;
    the incremental core must invalidate every shell's cached backlog
    (and steal-gate cache) when it does."""
    pol = PolicyConfig(preemptive=True, refine_cost_model=True,
                       transfer_ms=0.5)
    jobs = [SimJob(3.0 * i + (i % 3) * 0.1, f"t{i % 3}",
                   "skew" if i % 2 else "batch", 2 + i % 4)
            for i in range(14)]
    inc, full = _run_both({"a": (4, 1.0), "b": (4, 1.5)},
                          _rand_trace(seed, 6) + jobs, pol)
    assert inc == full


# -- 3. same-timestamp arrival coalescing -------------------------------------

def _one_module_registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="m", entrypoint="x:y",
        impls=(ImplAlt("f1", 1, 10.0), ImplAlt("f2", 2, 6.0))))
    return reg


def test_same_t_arrivals_admitted_before_placement():
    """Two jobs arriving at the same instant on a 2-slot shell both get
    a 1-slot implementation and run concurrently.  The pre-PR 6 core
    dispatched between the two same-t admissions, so the first job
    upsized to the full shell and its simultaneous peer queued behind
    it — an ordering bug: no event separates the arrivals."""
    reg = _one_module_registry()
    res = simulate(reg, 2, [SimJob(0.0, "a", "m", 1),
                            SimJob(0.0, "b", "m", 1)], PolicyConfig())
    spans = sorted(res.timeline, key=lambda e: e[2])
    assert len(spans) == 2
    # both start at t=0 in side-by-side 1-slot ranges
    assert [s[2] for s in spans] == [(0, 1), (1, 1)]
    assert all(s[0] == 0.0 for s in spans)
    assert res.makespan == spans[0][1] == spans[1][1]


def test_interleaved_admission_differs_from_coalesced():
    """Documents the bug the coalescing fixes: replaying the same two
    same-t submits with a dispatch in between (the old control flow)
    upsizes the first job onto both slots and starves its peer."""
    reg = _one_module_registry()
    fab = Fabric({"shell0": 2}, reg, PolicyConfig())
    fab.submit("a", "m", 1, now=0.0)
    first = fab.schedule(now=0.0)
    fab.submit("b", "m", 1, now=0.0)
    second = fab.schedule(now=0.0)
    assert [(a.footprint, a.rng.size) for _, a in first] == [(2, 2)]
    assert second == []                  # peer starved until a slot frees


def test_same_t_burst_equivalence_across_cores():
    """Coalescing happens in the simulator loop, upstream of the
    fabric — both scheduling cores see the identical admission batches,
    so same-t bursts stay byte-identical between them."""
    jobs = []
    for k in range(6):
        jobs += [SimJob(10.0 * k, f"t{i}", "inter", 1 + (k + i) % 3,
                        priority=2) for i in range(3)]
        jobs.append(SimJob(10.0 * k, "bb", "batch", 4))
    pol = PolicyConfig(preemptive=True, ckpt=True, transfer_ms=0.5)
    inc, full = _run_both({"a": (2, 1.0), "b": (2, 1.4)}, jobs, pol)
    assert inc == full


def test_arrivals_pop_before_dones_at_equal_t():
    """A job arriving exactly when the running chunk completes is
    admitted first (arrival seqs are assigned before any done event
    exists), so the completion's scheduling pass already sees it."""
    reg = _one_module_registry()
    # chunk time 10 + reconfig 5 = first completion at t=15.0 exactly
    res = simulate(reg, 1, [SimJob(0.0, "a", "m", 1),
                            SimJob(15.0, "b", "m", 1)], PolicyConfig())
    spans = sorted(res.timeline)
    assert spans[0][1] == 15.0
    # b starts at the completion instant, not one event later
    assert spans[1][0] == 15.0


# -- 4. bookkeeping under interleavings ---------------------------------------

@given(st.integers(0, 10**6))
@settings(max_examples=20, deadline=None)
def test_pending_counter_and_mask_track_slow_recompute(seed):
    """Drive a fabric through random submit/schedule/complete/abort
    interleavings (with preemption, stealing and checkpointing live)
    and cross-check, after every operation, the O(1) structures
    against their defining recomputations: `pending_chunks()` vs the
    queue scan, and the allocator bitmask vs the busy set."""
    rng = random.Random(seed)
    reg = build_registry()
    pol = PolicyConfig(preemptive=True, ckpt=True, steal=True,
                       transfer_ms=0.4, starvation_bound_ms=40.0)
    fab = Fabric({"a": (2, 1.0), "b": (2, 1.5)}, reg, pol)
    t = 0.0
    active = []
    gids = []

    def check():
        for st_ in fab.states.values():
            assert st_.pending_chunks() == st_._pending_chunks_slow()
            assert st_.pending_chunks() >= 0
            assert st_.alloc._mask == sum(1 << i for i in st_.alloc.busy)

    for _ in range(60):
        t += rng.uniform(0.1, 6.0)
        u = rng.random()
        if u < 0.45:
            mod = rng.choice(["batch", "inter", "wide"])
            pri = 2 if mod == "inter" else 0
            job = fab.submit(f"t{rng.randrange(3)}", mod,
                             rng.randint(1, 4), now=t, priority=pri)
            gids.append(job.gid)
        elif u < 0.75 and active:
            shell, a = active.pop(rng.randrange(len(active)))
            fab.complete(shell, a, now=t)   # False for stale: fine
        elif gids:
            gid = rng.choice(gids)          # repeats exercise the
            fab.abort(gid)                  # repeat-abort no-op guard
        check()
        active.extend(fab.schedule(now=t))
        fab.drain_preempted()
        check()
    # drain: complete everything still in flight
    while active:
        t += 1.0
        shell, a = active.pop()
        fab.complete(shell, a, now=t)
        active.extend(fab.schedule(now=t))
        fab.drain_preempted()
        check()


@given(st.integers(0, 10**6), st.integers(10, 18))
@settings(max_examples=15, deadline=None)
def test_heap_compaction_is_invisible(seed, n_jobs):
    """Force compaction on every preemption (threshold 0) on a
    preemption-heavy trace: the rebuilt heap must pop the surviving
    events in exactly the original order, so the run is byte-identical
    to the lazy-deletion run."""
    pol = PolicyConfig(preemptive=True, ckpt=True, transfer_ms=0.5)
    jobs = _rand_trace(seed, n_jobs)
    reg = build_registry()
    baseline = to_jsonable(simulate(
        reg, Fabric({"a": (2, 1.0), "b": (2, 0.8)}, reg, pol), jobs))
    orig = simulator_mod.COMPACT_MIN_STALE
    simulator_mod.COMPACT_MIN_STALE = 0
    try:
        reg2 = build_registry()
        forced = to_jsonable(simulate(
            reg2, Fabric({"a": (2, 1.0), "b": (2, 0.8)}, reg2, pol),
            jobs))
    finally:
        simulator_mod.COMPACT_MIN_STALE = orig
    assert forced == baseline


def test_bookkeeping_drains_on_preemption_storm():
    """A hi-prio stream that evicts nearly every batch chunk: the
    simulator's own end-of-run asserts (busy slots, in-flight chunks,
    checkpoint records, starts/charged/stale) are the oracle; the
    result must also be mode-independent."""
    jobs = [SimJob(0.0, "heavy", "batch", 10),
            SimJob(0.5, "heavy2", "batch", 8)]
    jobs += [SimJob(4.0 + 7.0 * i, "live", "inter", 1, priority=4)
             for i in range(12)]
    pol = PolicyConfig(preemptive=True, ckpt=True, steal=True,
                       transfer_ms=0.3)
    inc, full = _run_both({"a": (2, 1.0), "b": (2, 1.2)}, jobs, pol)
    assert inc == full
    reg = build_registry()
    res = simulate(reg, Fabric({"a": (2, 1.0), "b": (2, 1.2)},
                               reg, pol), jobs)
    assert res.preemptions > 0 and res.ckpt_restores > 0


def test_abort_is_idempotent_on_pending_counter():
    """Repeat aborts of the same request must not double-subtract the
    pending count (the bug class the `req.failed` guard closes)."""
    from repro.core.scheduler import SchedulerState
    st_ = SchedulerState(4, build_registry(), PolicyConfig())
    r1 = st_.submit("t0", "batch", 3, now=0.0)
    r2 = st_.submit("t1", "batch", 2, now=0.0)
    assert st_.pending_chunks() == st_._pending_chunks_slow() == 5
    st_.abort(r1.rid)
    assert st_.pending_chunks() == st_._pending_chunks_slow() == 2
    st_.abort(r1.rid)                     # repeat: must be a no-op
    st_.abort(r1.rid)
    assert st_.pending_chunks() == st_._pending_chunks_slow() == 2
    st_.abort(r2.rid)
    assert st_.pending_chunks() == st_._pending_chunks_slow() == 0


def test_resteal_releases_transfer_charge():
    """Steal -> evict -> re-steal of the same transfer-paid chunk: the
    re-steal retires the chunk's old (shell, rid, chunk) identity, and
    the simulator must release its transfer-charge record — the
    end-of-run `not paid_chunks` assert inside simulate() is the
    oracle (before the drain_moved fix this scenario left residue).

    Forced deterministically: a batch job pinned to "a" overflows; "b"
    and "c" each steal one chunk and pay the 5 ms transfer at dispatch;
    a high-priority burst pinned to "b" evicts b's paid chunk while it
    is still queued behind the burst; fast "c" goes idle first and
    re-steals that exact chunk from "b"."""
    reg = build_registry()
    pol = PolicyConfig(preemptive=True, transfer_ms=5.0)
    fab = Fabric({"a": (2, 1.0), "b": (1, 1.0), "c": (1, 4.0)}, reg, pol)
    jobs = [SimJob(0.0, "bulk", "batch", 4, affinity="a"),
            SimJob(1.0, "live", "inter", 5, priority=5, affinity="b")]
    res = simulate(reg, fab, jobs)
    assert res.preemptions >= 1           # b's stolen chunk was evicted
    assert res.stolen_chunks >= 3         # b, c, then c again (re-steal)
    (bulk,) = [j for j in fab.jobs.values() if j.tenant == "bulk"]
    # primary on a, steals onto b and c, and the re-steal onto c again
    assert len(bulk.subs) >= 4
    shells = [s for s, _ in bulk.subs]
    assert shells.count("c") >= 2
