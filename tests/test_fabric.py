"""Fabric: one scheduling contract over many shells.

Multi-shell invariants of core/fabric.py:
  - the degenerate one-shell fabric reproduces the seed single-shell
    `simulate` byte-for-byte (same ids, same event order, same floats);
  - every chunk completes exactly once across shells under preemption +
    work stealing, and no shell's slots are ever double-booked;
  - cross-shell stealing beats static per-shell partitioning by >= 1.2x
    makespan on a skewed two-shell workload (the acceptance bound the
    benchmark enforces too);
  - locality-aware dispatch prefers the shell already hosting a module;
  - `JobHandle.t_submit` and the scheduler clock share units (ms);
  - `PolicyConfig.refine_cost_model` converges a mis-estimated module's
    `est_chunk_ms` onto the observed chunk times;
  - fabrics are registered, serialisable descriptors (fabrics.json).
"""
from __future__ import annotations

from collections import Counter

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import Daemon, Fabric, FabricDescriptor, ImplAlt, \
    ModuleDescriptor, PolicyConfig, Registry, Shell, SimJob, \
    default_registry, simulate, uniform_shell
from repro.core.daemon import _now_ms


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.4))))
    return reg


def _check_spans_consistent(res, n_slots: int) -> None:
    """Capacity + no double-booking over completed AND evicted spans
    (shells occupy disjoint offset ranges on the global slot axis)."""
    spans = list(res.timeline) + list(res.preempted_spans)
    events = []
    for t0, t1, (s, size), _ in spans:
        events += [(t0, size), (t1, -size)]
    busy = 0
    for _, d in sorted(events, key=lambda e: (e[0], e[1])):
        busy += d
        assert busy <= n_slots
    per_slot: dict[int, list] = {}
    for t0, t1, (s, size), _ in spans:
        for i in range(s, s + size):
            per_slot.setdefault(i, []).append((t0, t1))
    for slot_spans in per_slot.values():
        slot_spans.sort()
        for (a0, a1), (b0, b1) in zip(slot_spans, slot_spans[1:]):
            assert b0 >= a1 - 1e-9, "slot double-booked"


# -- seed equivalence ---------------------------------------------------------

seed_jobs_strategy = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, 15.0, 60.0])),
    min_size=1, max_size=15)


@given(seed_jobs_strategy, st.sampled_from([1, 2, 4]), st.booleans())
@settings(max_examples=60, deadline=None)
def test_single_shell_fabric_matches_seed_simulate(raw, n_slots,
                                                   preemptive):
    """`simulate(reg, n_slots, ...)` and an explicitly-built one-shell
    Fabric must agree on every metric, byte for byte."""
    jobs = [SimJob(t, u, m, c, priority=p, deadline_ms=d)
            for t, u, m, c, p, d in raw]
    pol = PolicyConfig(preemptive=preemptive)
    a = simulate(_registry(), n_slots, jobs, pol)
    fab = Fabric({"shell0": n_slots}, _registry(), pol)
    b = simulate(_registry(), fab, jobs)
    assert a.makespan == b.makespan
    assert a.utilization == b.utilization
    assert a.reconfigurations == b.reconfigurations
    assert a.request_latency == b.request_latency
    assert a.timeline == b.timeline
    assert a.preemptions == b.preemptions
    assert a.preempted_spans == b.preempted_spans
    assert a.wasted_time == b.wasted_time
    assert a.request_meta == b.request_meta
    assert a.per_shell == b.per_shell
    assert a.stolen_chunks == b.stolen_chunks == 0


# -- multi-shell exactly-once -------------------------------------------------

multi_jobs_strategy = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, "a", "b"])),
    min_size=1, max_size=15)


@given(multi_jobs_strategy,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]))
@settings(max_examples=60, deadline=None)
def test_every_chunk_completes_exactly_once_across_shells(raw, sizes):
    """Preemption + stealing + affinity over two shells: each submitted
    chunk still completes exactly once, capacity is never exceeded."""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    shells = {"a": sizes[0], "b": sizes[1]}
    res = simulate(_registry(), shells, jobs,
                   PolicyConfig(preemptive=True, steal=True))
    done = Counter(rid for *_, rid in res.timeline)
    for rid, meta in res.request_meta.items():
        assert done[rid] == meta["n_chunks"], \
            f"rid {rid}: {done[rid]} completions != {meta['n_chunks']}"
    assert res.preemptions == len(res.preempted_spans)
    _check_spans_consistent(res, sum(sizes))


def test_stealing_improves_skewed_makespan():
    """Acceptance: >= 1.2x makespan improvement from stealing vs static
    per-shell partitioning on a skewed two-shell workload."""
    reg = _registry()
    jobs = [SimJob(2.0 * i, "heavy", "batch", 6, affinity="s0")
            for i in range(10)]
    jobs += [SimJob(0.0, "light", "inter", 2, affinity="s1")]
    shells = {"s0": 2, "s1": 2}
    static = simulate(reg, shells, jobs, PolicyConfig(steal=False))
    steal = simulate(reg, shells, jobs, PolicyConfig(steal=True))
    assert steal.stolen_chunks > 0
    speedup = static.makespan / steal.makespan
    assert speedup >= 1.2, f"stealing speedup {speedup:.2f}x < 1.2x"
    # the idle shell actually absorbed work
    assert steal.per_shell["s1"]["utilization"] > \
        static.per_shell["s1"]["utilization"] + 0.2


def test_locality_prefers_resident_shell():
    """A job with no affinity goes to the shell already hosting its
    module resident (dodging the reconfiguration penalty); with
    locality off, dispatch is purely least-loaded (first shell wins
    the tie)."""
    for locality, expect_shell in ((True, "b"), (False, "a")):
        reg = _registry()
        fab = Fabric({"a": 2, "b": 2}, reg,
                     PolicyConfig(steal=False, locality=locality))
        fab.submit("t0", "inter", 1, now=0.0, affinity="b")
        [(shell, a0)] = fab.schedule(now=0.0)
        assert shell == "b"
        fab.complete("b", a0, now=10.0)
        fab.submit("t1", "inter", 1, now=20.0)      # no affinity
        [(shell, _)] = fab.schedule(now=20.0)
        assert shell == expect_shell, \
            f"locality={locality} dispatched to {shell}"


def test_fabric_affinity_unknown_shell_raises():
    fab = Fabric({"a": 1}, _registry())
    with pytest.raises(KeyError, match="unknown shell"):
        fab.submit("t", "inter", 1, affinity="nope")


# -- registry descriptors -----------------------------------------------------

def test_registry_shell_unknown_message():
    reg = default_registry()
    with pytest.raises(KeyError, match="unknown shell 'nope'"):
        reg.shell("nope")
    with pytest.raises(KeyError, match="registered"):
        reg.shell("nope")


def test_registry_fabric_descriptor_roundtrip(tmp_path):
    reg = default_registry()
    assert reg.fabric("hostpair").shells == ("host8_s4", "host4_s4")
    with pytest.raises(KeyError, match="unknown fabric"):
        reg.fabric("nope")
    # a fabric may only reference registered shells
    with pytest.raises(KeyError, match="unknown shell"):
        reg.register_fabric(FabricDescriptor("bad", ("ghost",)))
    reg.save(tmp_path)
    reg2 = Registry.load(tmp_path)
    assert set(reg2.fabrics) == set(reg.fabrics)
    fab = Fabric.from_registry(reg2, "hostpair")
    assert [st.alloc.n for st in fab.states.values()] == [4, 4]
    # pre-fabric saves (no fabrics.json) still load
    (tmp_path / "fabrics.json").unlink()
    reg3 = Registry.load(tmp_path)
    assert reg3.fabrics == {}


# -- cost-model refinement ----------------------------------------------------

def test_cost_model_refinement_converges():
    """A module whose registry estimate is 10x the true chunk time
    converges onto the observed times when refine_cost_model is on,
    and keeps the static estimate when it is off."""
    def mk_reg():
        reg = Registry()
        reg.register_module(ModuleDescriptor(
            name="m", entrypoint="x:y",
            impls=(ImplAlt("x1", 1, 50.0,
                           meta={"true_chunk_ms": 5.0}),)))
        return reg

    reg = mk_reg()
    fab = Fabric({"s": 1}, reg, PolicyConfig(refine_cost_model=True))
    jobs = [SimJob(100.0 * i, "t", "m", 4) for i in range(4)]
    simulate(reg, fab, jobs)
    assert abs(fab.cost.est_chunk_ms("m", 1) - 5.0) < 1.0, \
        f"did not converge: {fab.cost.est_chunk_ms('m', 1)}"

    reg2 = mk_reg()
    fab2 = Fabric({"s": 1}, reg2, PolicyConfig(refine_cost_model=False))
    simulate(reg2, fab2, jobs)
    assert fab2.cost.est_chunk_ms("m", 1) == 50.0


def test_daemon_refines_cost_model_from_wall_times():
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg, PolicyConfig(refine_cost_model=True))
    try:
        rng = np.random.default_rng(0)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        h = d.submit("t", "mandelbrot", [(re, im)] * 3)
        assert len(h.future.result(timeout=300)) == 3
        with d._lock:
            # the first chunk reconfigures (not observed); later reuse
            # chunks feed the EWMA with real wall times
            assert ("mandelbrot", 1) in d.fabric.cost._est
            assert d.fabric.cost.est_chunk_ms("mandelbrot", 1) > 0.0
    finally:
        d.shutdown()


# -- daemon over a fabric -----------------------------------------------------

def test_jobhandle_and_scheduler_share_ms_clock():
    """Regression: JobHandle.t_submit was perf_counter() *seconds* while
    the scheduler clock is milliseconds; both now use _now_ms()."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg)
    try:
        rng = np.random.default_rng(2)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        before = _now_ms()
        h = d.submit("t", "mandelbrot", [(re, im)])
        after = _now_ms()
        assert before <= h.t_submit <= after
        assert len(h.future.result(timeout=300)) == 1
        with d._lock:
            req = d.state.requests[h.rid]
            # the scheduler request is stamped with the handle's clock
            assert req.t_submit == h.t_submit
    finally:
        d.shutdown()


def test_multi_shell_daemon_exactly_once():
    """Two live shells (sharing the single CPU device): affinity routes
    jobs, stealing may rebalance, and every chunk resolves exactly once
    with consistent fabric state afterwards."""
    import jax
    devs = jax.devices()
    shells = {"a": Shell(uniform_shell("fa", (1, 1), 1), devs),
              "b": Shell(uniform_shell("fb", (1, 1), 1), devs)}
    reg = default_registry()
    d = Daemon(shells, reg, PolicyConfig())
    try:
        rng = np.random.default_rng(3)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        img = rng.random((1024, 1024)).astype(np.float32)
        h1 = d.submit("heavy", "mandelbrot", [(re, im)] * 4,
                      affinity="a")
        h2 = d.submit("light", "sobel", [(img,)], affinity="b")
        out1 = h1.future.result(timeout=300)
        out2 = h2.future.result(timeout=300)
        assert len(out1) == 4 and len(out2) == 1
        assert all(np.asarray(o).shape == (256, 256) for o in out1)
        assert np.asarray(out2[0]).shape == (1024, 1024)
        with d._lock:
            assert not d._results and not d._handles
            for st in d.fabric.states.values():
                assert not st.alloc.busy and not st.active
            assert all(j.complete for j in d.fabric.jobs.values())
        # exactly-once even if idle shell b stole heavy chunks
        assert d.stats["chunks"] == 5
    finally:
        d.shutdown()
