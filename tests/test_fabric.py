"""Fabric: one scheduling contract over many shells.

Multi-shell invariants of core/fabric.py:
  - the degenerate one-shell fabric reproduces the seed single-shell
    `simulate` byte-for-byte (same ids, same event order, same floats);
  - every chunk completes exactly once across shells under preemption +
    work stealing, and no shell's slots are ever double-booked;
  - cross-shell stealing beats static per-shell partitioning by >= 1.2x
    makespan on a skewed two-shell workload (the acceptance bound the
    benchmark enforces too);
  - locality-aware dispatch prefers the shell already hosting a module;
  - `JobHandle.t_submit` and the scheduler clock share units (ms);
  - `PolicyConfig.refine_cost_model` converges a mis-estimated module's
    `est_chunk_ms` onto the observed chunk times — including a module
    that reconfigures on every chunk (observed at elapsed - penalty);
  - fabrics are registered, serialisable descriptors (fabrics.json);
  - heterogeneity: per-shell `speed` scales true chunk times and drives
    ECT-based placement; cross-shell `transfer_ms` prices stealing; the
    all-speeds-1.0 / transfer-0.0 fabric is byte-identical to the
    homogeneous contract;
  - dispatch feasibility: a shell the module's smallest footprint can
    never fit is excluded, and an infeasible `affinity=` pin raises at
    submit instead of wedging the executor.
"""
from __future__ import annotations

from collections import Counter

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import Daemon, Fabric, FabricDescriptor, FabricJob, \
    ImplAlt, ModuleDescriptor, PolicyConfig, Registry, Shell, SimJob, \
    default_registry, simulate, uniform_shell
from repro.core.daemon import _now_ms


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.4))))
    # smallest footprint 2: can never fit a 1-slot shell
    reg.register_module(ModuleDescriptor(
        name="wide", entrypoint="x:y",
        impls=(ImplAlt("x2", 2, 10.0),)))
    return reg


def _check_spans_consistent(res, n_slots: int) -> None:
    """Capacity + no double-booking over completed AND evicted spans
    (shells occupy disjoint offset ranges on the global slot axis)."""
    spans = list(res.timeline) + list(res.preempted_spans)
    events = []
    for t0, t1, (s, size), _ in spans:
        events += [(t0, size), (t1, -size)]
    busy = 0
    for _, d in sorted(events, key=lambda e: (e[0], e[1])):
        busy += d
        assert busy <= n_slots
    per_slot: dict[int, list] = {}
    for t0, t1, (s, size), _ in spans:
        for i in range(s, s + size):
            per_slot.setdefault(i, []).append((t0, t1))
    for slot_spans in per_slot.values():
        slot_spans.sort()
        for (a0, a1), (b0, b1) in zip(slot_spans, slot_spans[1:]):
            assert b0 >= a1 - 1e-9, "slot double-booked"


# -- seed equivalence ---------------------------------------------------------

seed_jobs_strategy = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, 15.0, 60.0])),
    min_size=1, max_size=15)


@given(seed_jobs_strategy, st.sampled_from([1, 2, 4]), st.booleans())
@settings(max_examples=60, deadline=None)
def test_single_shell_fabric_matches_seed_simulate(raw, n_slots,
                                                   preemptive):
    """`simulate(reg, n_slots, ...)` and an explicitly-built one-shell
    Fabric must agree on every metric, byte for byte."""
    jobs = [SimJob(t, u, m, c, priority=p, deadline_ms=d)
            for t, u, m, c, p, d in raw]
    pol = PolicyConfig(preemptive=preemptive)
    a = simulate(_registry(), n_slots, jobs, pol)
    fab = Fabric({"shell0": n_slots}, _registry(), pol)
    b = simulate(_registry(), fab, jobs)
    assert a.makespan == b.makespan
    assert a.utilization == b.utilization
    assert a.reconfigurations == b.reconfigurations
    assert a.request_latency == b.request_latency
    assert a.timeline == b.timeline
    assert a.preemptions == b.preemptions
    assert a.preempted_spans == b.preempted_spans
    assert a.wasted_time == b.wasted_time
    assert a.request_meta == b.request_meta
    assert a.per_shell == b.per_shell
    assert a.stolen_chunks == b.stolen_chunks == 0


# -- multi-shell exactly-once -------------------------------------------------

multi_jobs_strategy = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, "a", "b"])),
    min_size=1, max_size=15)


@given(multi_jobs_strategy,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]))
@settings(max_examples=60, deadline=None)
def test_every_chunk_completes_exactly_once_across_shells(raw, sizes):
    """Preemption + stealing + affinity over two shells: each submitted
    chunk still completes exactly once, capacity is never exceeded."""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    shells = {"a": sizes[0], "b": sizes[1]}
    res = simulate(_registry(), shells, jobs,
                   PolicyConfig(preemptive=True, steal=True))
    done = Counter(rid for *_, rid in res.timeline)
    for rid, meta in res.request_meta.items():
        assert done[rid] == meta["n_chunks"], \
            f"rid {rid}: {done[rid]} completions != {meta['n_chunks']}"
    assert res.preemptions == len(res.preempted_spans)
    _check_spans_consistent(res, sum(sizes))


def test_stealing_improves_skewed_makespan():
    """Acceptance: >= 1.2x makespan improvement from stealing vs static
    per-shell partitioning on a skewed two-shell workload."""
    reg = _registry()
    jobs = [SimJob(2.0 * i, "heavy", "batch", 6, affinity="s0")
            for i in range(10)]
    jobs += [SimJob(0.0, "light", "inter", 2, affinity="s1")]
    shells = {"s0": 2, "s1": 2}
    static = simulate(reg, shells, jobs, PolicyConfig(steal=False))
    steal = simulate(reg, shells, jobs, PolicyConfig(steal=True))
    assert steal.stolen_chunks > 0
    speedup = static.makespan / steal.makespan
    assert speedup >= 1.2, f"stealing speedup {speedup:.2f}x < 1.2x"
    # the idle shell actually absorbed work
    assert steal.per_shell["s1"]["utilization"] > \
        static.per_shell["s1"]["utilization"] + 0.2


def test_locality_prefers_resident_shell():
    """A job with no affinity goes to the shell already hosting its
    module resident (dodging the reconfiguration penalty); with
    locality off, dispatch is purely least-loaded (first shell wins
    the tie)."""
    for locality, expect_shell in ((True, "b"), (False, "a")):
        reg = _registry()
        fab = Fabric({"a": 2, "b": 2}, reg,
                     PolicyConfig(steal=False, locality=locality))
        fab.submit("t0", "inter", 1, now=0.0, affinity="b")
        [(shell, a0)] = fab.schedule(now=0.0)
        assert shell == "b"
        fab.complete("b", a0, now=10.0)
        fab.submit("t1", "inter", 1, now=20.0)      # no affinity
        [(shell, _)] = fab.schedule(now=20.0)
        assert shell == expect_shell, \
            f"locality={locality} dispatched to {shell}"


def test_fabric_affinity_unknown_shell_raises():
    fab = Fabric({"a": 1}, _registry())
    with pytest.raises(KeyError, match="unknown shell"):
        fab.submit("t", "inter", 1, affinity="nope")


# -- registry descriptors -----------------------------------------------------

def test_registry_shell_unknown_message():
    reg = default_registry()
    with pytest.raises(KeyError, match="unknown shell 'nope'"):
        reg.shell("nope")
    with pytest.raises(KeyError, match="registered"):
        reg.shell("nope")


def test_registry_fabric_descriptor_roundtrip(tmp_path):
    reg = default_registry()
    assert reg.fabric("hostpair").shells == ("host8_s4", "host4_s4")
    with pytest.raises(KeyError, match="unknown fabric"):
        reg.fabric("nope")
    # a fabric may only reference registered shells
    with pytest.raises(KeyError, match="unknown shell"):
        reg.register_fabric(FabricDescriptor("bad", ("ghost",)))
    reg.save(tmp_path)
    reg2 = Registry.load(tmp_path)
    assert set(reg2.fabrics) == set(reg.fabrics)
    fab = Fabric.from_registry(reg2, "hostpair")
    assert [st.alloc.n for st in fab.states.values()] == [4, 4]
    # pre-fabric saves (no fabrics.json) still load
    (tmp_path / "fabrics.json").unlink()
    reg3 = Registry.load(tmp_path)
    assert reg3.fabrics == {}


# -- cost-model refinement ----------------------------------------------------

def test_cost_model_refinement_converges():
    """A module whose registry estimate is 10x the true chunk time
    converges onto the observed times when refine_cost_model is on,
    and keeps the static estimate when it is off."""
    def mk_reg():
        reg = Registry()
        reg.register_module(ModuleDescriptor(
            name="m", entrypoint="x:y",
            impls=(ImplAlt("x1", 1, 50.0,
                           meta={"true_chunk_ms": 5.0}),)))
        return reg

    reg = mk_reg()
    fab = Fabric({"s": 1}, reg, PolicyConfig(refine_cost_model=True))
    jobs = [SimJob(100.0 * i, "t", "m", 4) for i in range(4)]
    simulate(reg, fab, jobs)
    assert abs(fab.cost.est_chunk_ms("m", 1) - 5.0) < 1.0, \
        f"did not converge: {fab.cost.est_chunk_ms('m', 1)}"

    reg2 = mk_reg()
    fab2 = Fabric({"s": 1}, reg2, PolicyConfig(refine_cost_model=False))
    simulate(reg2, fab2, jobs)
    assert fab2.cost.est_chunk_ms("m", 1) == 50.0


def test_daemon_refines_cost_model_from_wall_times():
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg, PolicyConfig(refine_cost_model=True))
    try:
        rng = np.random.default_rng(0)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        h = d.submit("t", "mandelbrot", [(re, im)] * 3)
        assert len(h.future.result(timeout=300)) == 3
        with d._lock:
            # the first chunk reconfigures (not observed); later reuse
            # chunks feed the EWMA with real wall times
            assert ("mandelbrot", 1) in d.fabric.cost._est
            assert d.fabric.cost.est_chunk_ms("mandelbrot", 1) > 0.0
    finally:
        d.shutdown()


# -- heterogeneity: speeds, transfer cost, ECT placement ----------------------

@given(multi_jobs_strategy,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_speed_one_transfer_zero_matches_homogeneous(raw, sizes,
                                                     preemptive):
    """Every construction spelling of a homogeneous fabric — plain slot
    counts, `(n_slots, 1.0)` tuples, explicit zero per-pair transfer
    overrides — must agree byte-for-byte.  (PR 2 identity itself is
    anchored separately: the single-shell path by the seed-equivalence
    test above, the steal contract by
    test_homogeneous_steal_contract_pins_pr2_values; dispatch ranking
    deliberately changed, see
    test_homogeneous_dispatch_weighs_queues_by_estimated_work.)"""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    a = simulate(_registry(), {"a": sizes[0], "b": sizes[1]}, jobs,
                 PolicyConfig(preemptive=preemptive, steal=True))
    fab = Fabric({"a": (sizes[0], 1.0), "b": (sizes[1], 1.0)},
                 _registry(),
                 PolicyConfig(preemptive=preemptive, steal=True,
                              transfer_ms=0.0),
                 transfer={("a", "b"): 0.0, "b->a": 0.0})
    b = simulate(_registry(), fab, jobs)
    assert a.makespan == b.makespan
    assert a.utilization == b.utilization
    assert a.reconfigurations == b.reconfigurations
    assert a.request_latency == b.request_latency
    assert a.timeline == b.timeline
    assert a.preemptions == b.preemptions
    assert a.preempted_spans == b.preempted_spans
    assert a.wasted_time == b.wasted_time
    assert a.per_shell == b.per_shell
    assert a.stolen_chunks == b.stolen_chunks


@given(multi_jobs_strategy,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]),
       st.sampled_from([(0.5, 2.0), (1.0, 0.25), (2.0, 1.0)]))
@settings(max_examples=60, deadline=None)
def test_exactly_once_under_mixed_speeds(raw, sizes, speeds):
    """Preemption + stealing + affinity over shells of different speeds
    and a nonzero transfer cost: every chunk still completes exactly
    once and capacity is never exceeded."""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    shells = {"a": (sizes[0], speeds[0]), "b": (sizes[1], speeds[1])}
    res = simulate(_registry(), shells, jobs,
                   PolicyConfig(preemptive=True, steal=True,
                                transfer_ms=1.0))
    done = Counter(rid for *_, rid in res.timeline)
    for rid, meta in res.request_meta.items():
        assert done[rid] == meta["n_chunks"], \
            f"rid {rid}: {done[rid]} completions != {meta['n_chunks']}"
    assert res.preemptions == len(res.preempted_spans)
    _check_spans_consistent(res, sum(sizes))


def test_simulator_scales_chunk_time_by_speed():
    """True chunk time is est/speed; the reconfiguration penalty is
    speed-independent (the configuration port does not scale)."""
    for speed, expect in ((1.0, 45.0), (2.0, 25.0), (0.5, 85.0)):
        res = simulate(_registry(), {"s": (1, speed)},
                       [SimJob(0.0, "t", "batch", 1)])
        assert res.makespan == expect, (speed, res.makespan)


def test_ect_placement_prefers_fast_shell():
    """With speed awareness, an idle slow shell loses the dispatch to a
    fast shell that finishes sooner; a speed-blind policy falls back to
    the declaration-order tie-break and parks the job on the slow
    shell."""
    for aware, expect in ((True, "fast"), (False, "slow")):
        fab = Fabric({"slow": (1, 0.25), "fast": (1, 1.0)}, _registry(),
                     PolicyConfig(locality=False, steal=False,
                                  speed_aware=aware))
        fab.submit("t", "inter", 1, now=0.0)
        [(shell, _)] = fab.schedule(now=0.0)
        assert shell == expect, f"speed_aware={aware} -> {shell}"


def test_homogeneous_dispatch_weighs_queues_by_estimated_work():
    """Pin the deliberate homogeneous-path change to dispatch: ECT
    ranking weighs queued work in estimated milliseconds, so a few
    cheap pending chunks beat fewer expensive ones (PR 2's raw
    chunk-count load ranking chose the other shell)."""
    fab = Fabric({"a": 1, "b": 1}, _registry(),
                 PolicyConfig(locality=False, steal=False))
    fab.submit("t0", "batch", 3, now=0.0, affinity="a")  # 40 ms chunks
    fab.submit("t1", "inter", 4, now=0.0, affinity="b")  # 4 ms chunks
    fab.schedule(now=0.0)
    # a: 1 in-flight + 2 pending batch (~125 est-ms); b: 1 in-flight +
    # 3 pending inter (~21 est-ms).  PR 2 load ranking: a has fewer
    # chunks (3 < 4) -> a.  ECT: b clears sooner -> b.
    j = fab.submit("t2", "inter", 1, now=0.0)
    fab.schedule(now=0.0)
    assert fab.jobs[j.gid].subs[0][0] == "b"


def test_transfer_not_recharged_on_preempted_rerun():
    """Transfer is paid once per stolen chunk: a preempted rerun of the
    same chunk does not move the payload (or pay the cost) again."""
    jobs = [SimJob(0.0, "lo", "batch", 2, affinity="v", priority=0),
            SimJob(1.0, "hi", "inter", 1, affinity="t", priority=5)]
    res = simulate(_registry(), {"v": 1, "t": 1}, jobs,
                   PolicyConfig(steal=True, preemptive=True,
                                transfer_ms=10.0))
    # chunk 1 is stolen onto t (paying 10 ms transfer), evicted by the
    # priority-5 arrival, and re-run on t without paying transfer
    # again: 45 ms rerun starting when "hi" finishes at t=10.
    assert res.stolen_chunks == 1 and res.preemptions == 1
    assert res.makespan == 55.0, res.makespan


def test_homogeneous_steal_contract_pins_pr2_values():
    """Regression: the steal-economics gate must be inert at transfer 0
    and equal speeds.  Under the PR 2 contract this exact trace steals
    one chunk and finishes at 9.0 ms; an over-eager gate (pricing the
    thief's reconfiguration against a small backlog) skipped the steal
    and regressed the makespan to 13.0 ms."""
    res = simulate(_registry(), {"v": 1, "t": 1},
                   [SimJob(0.0, "t0", "inter", 2, affinity="v")],
                   PolicyConfig(steal=True))
    assert res.stolen_chunks == 1
    assert res.makespan == 9.0


def test_steal_skipped_when_transfer_cost_loses():
    """A thief whose transfer cost + service time cannot beat the victim
    draining its own backlog must not steal; with transfer 0 the same
    trace steals."""
    jobs = [SimJob(0.0, "t", "batch", 4, affinity="v")]
    shells = {"v": 1, "t": 1}
    free = simulate(_registry(), shells, jobs,
                    PolicyConfig(steal=True, transfer_ms=0.0))
    assert free.stolen_chunks > 0
    priced = simulate(_registry(), shells, jobs,
                      PolicyConfig(steal=True, transfer_ms=1000.0))
    assert priced.stolen_chunks == 0
    no_steal = simulate(_registry(), shells, jobs,
                        PolicyConfig(steal=False))
    assert priced.makespan == no_steal.makespan
    assert free.makespan < priced.makespan


def test_simulator_realizes_transfer_latency():
    """The priced transfer cost is charged to the stolen chunk's
    simulated time — and excluded from refinement observations — not
    just used to gate the steal decision."""
    jobs = [SimJob(0.0, "t0", "batch", 2, affinity="v")]
    shells = {"v": 1, "t": 1}
    free = simulate(_registry(), shells, jobs, PolicyConfig(steal=True))
    fab = Fabric(shells, _registry(),
                 PolicyConfig(steal=True, transfer_ms=10.0,
                              refine_cost_model=True))
    priced = simulate(_registry(), fab, jobs)
    assert free.stolen_chunks == priced.stolen_chunks == 1
    assert priced.makespan == free.makespan + 10.0
    # the observation backs out penalty + transfer: est stays exact
    assert fab.cost.est_chunk_ms("batch", 1) == 40.0


def test_per_pair_transfer_override():
    """FabricDescriptor/Fabric per-pair transfer costs override the
    PolicyConfig default, per direction."""
    fab = Fabric({"a": 1, "b": 1}, _registry(),
                 PolicyConfig(transfer_ms=3.0),
                 transfer={"a->b": 7.0})
    assert fab.est_transfer_ms("a", "b") == 7.0
    assert fab.est_transfer_ms("b", "a") == 3.0  # policy default
    with pytest.raises(ValueError, match="transfer pair"):
        Fabric({"a": 1}, _registry(), transfer={"a->ghost": 1.0})


def test_hetero_fabric_from_registry():
    """Shell speeds come from the ShellSpecs and per-pair transfer costs
    from the FabricDescriptor; both survive a save/load roundtrip."""
    reg = default_registry()
    fab = Fabric.from_registry(reg, "hostpair_hetero")
    assert fab.speeds == {"host8_s4": 1.0, "host8_s4_lowclk": 0.5}
    assert fab.est_transfer_ms("host8_s4", "host8_s4_lowclk") == 2.0
    with pytest.raises(ValueError, match="transfer pair"):
        reg.register_fabric(FabricDescriptor(
            "bad", ("host8_s4",), transfer_ms={"host8_s4->ghost": 1.0}))
    # tuple keys would crash every later save(): rejected up front
    with pytest.raises(ValueError, match="strings"):
        reg.register_fabric(FabricDescriptor(
            "bad2", ("host8_s4", "host4_s4"),
            transfer_ms={("host8_s4", "host4_s4"): 1.0}))


def test_shellspec_speed_json_roundtrip(tmp_path):
    reg = default_registry()
    reg.save(tmp_path)
    reg2 = Registry.load(tmp_path)
    assert reg2.shell("host8_s4_lowclk").speed == 0.5
    assert reg2.shell("host8_s4").speed == 1.0
    assert reg2.fabric("hostpair_hetero").transfer_ms == \
        reg.fabric("hostpair_hetero").transfer_ms


# -- dispatch feasibility (regression: unplaceable-forever jobs) --------------

def test_dispatch_skips_too_small_shell():
    """Regression: least-loaded dispatch used to pick the 1-slot shell
    for a footprint-2 module (load tie, declaration order), wedging the
    simulator with an unplaceable job.  Too-small shells are excluded
    now."""
    res = simulate(_registry(), {"small": 1, "big": 2},
                   [SimJob(0.0, "t", "wide", 2)])
    assert res.request_latency and res.makespan > 0
    assert res.per_shell["big"]["busy_ms"] > 0
    assert res.per_shell["small"]["busy_ms"] == 0


def test_infeasible_affinity_raises_at_submit():
    """An affinity pin to a shell the module can never fit fails fast
    with ValueError instead of queueing forever."""
    fab = Fabric({"small": 1, "big": 2}, _registry())
    with pytest.raises(ValueError, match="unplaceable forever"):
        fab.submit("t", "wide", 1, affinity="small")
    # no shell at all can host the module -> same failure, no affinity
    fab1 = Fabric({"small": 1}, _registry())
    with pytest.raises(ValueError, match="unplaceable forever"):
        fab1.submit("t", "wide", 1)


def test_daemon_infeasible_affinity_raises():
    """Regression: the daemon future for an unplaceable job never
    resolved; submit now raises before any state is created."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    reg.register_module(ModuleDescriptor(
        name="wide", entrypoint="x:y",
        impls=(ImplAlt("x2", 2, 1.0),)))
    d = Daemon(Shell(spec), reg)
    try:
        with pytest.raises(ValueError, match="unplaceable forever"):
            d.submit("t", "wide", [(None,)], affinity="host1_s1")
        with d._lock:
            assert not d._handles and not d._results
    finally:
        d.shutdown()


# -- FabricJob identity (regression: value-eq admission membership) -----------

def test_fabricjob_membership_is_identity_based():
    """FabricJob compares by identity: two field-identical jobs are
    distinct queue entries, and `finished()` stays correct for a job
    aborted before dispatch."""
    a = FabricJob(0, "t", "m", 1)
    b = FabricJob(0, "t", "m", 1)
    assert a != b and a == a               # eq=False: identity semantics
    fab = Fabric({"s": 1}, _registry())
    j1 = fab.submit("t", "inter", 1, now=0.0)
    j2 = fab.submit("t", "inter", 1, now=0.0)
    fab.abort(j2.gid)
    # undispatched + failed -> finished; the live j1 is not
    assert fab.finished(j2.gid)
    assert not fab.finished(j1.gid)
    [(shell, a0)] = fab.schedule(now=0.0)
    assert fab.jobs[j1.gid].subs and not fab.jobs[j2.gid].subs
    assert fab.complete(shell, a0, now=1.0)
    assert fab.finished(j1.gid)


# -- refinement observes reconfigured chunks (regression) ---------------------

def test_refinement_converges_for_always_reconfiguring_module():
    """A module that pays the reconfiguration penalty on every chunk
    (ping-ponging residency on one slot) used to never refine its
    estimate; it now observes elapsed - penalty and converges."""
    def mk_reg():
        reg = Registry()
        for name in ("ping", "pong"):
            reg.register_module(ModuleDescriptor(
                name=name, entrypoint="x:y",
                impls=(ImplAlt("x1", 1, 50.0,
                               meta={"true_chunk_ms": 5.0}),)))
        return reg

    jobs = [SimJob(200.0 * i, "t", "ping" if i % 2 == 0 else "pong", 1)
            for i in range(8)]
    reg = mk_reg()
    fab = Fabric({"s": 1}, reg, PolicyConfig(refine_cost_model=True))
    res = simulate(reg, fab, jobs)
    assert res.reconfigurations == len(jobs)    # every chunk reconfigured
    assert abs(fab.cost.est_chunk_ms("ping", 1) - 5.0) < 1.0, \
        f"did not converge: {fab.cost.est_chunk_ms('ping', 1)}"

    reg2 = mk_reg()
    fab2 = Fabric({"s": 1}, reg2, PolicyConfig(refine_cost_model=False))
    simulate(reg2, fab2, jobs)
    assert fab2.cost.est_chunk_ms("ping", 1) == 50.0


def test_daemon_refines_always_reconfiguring_module():
    """Daemon analogue: alternating modules on one slot reconfigure on
    every chunk, and both still feed the shared cost model."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg, PolicyConfig(refine_cost_model=True))
    try:
        rng = np.random.default_rng(7)
        re = rng.uniform(-2, 1, (128, 128)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (128, 128)).astype(np.float32)
        img = rng.random((256, 256)).astype(np.float32)
        for module, chunk in (("mandelbrot", (re, im)), ("sobel", (img,)),
                              ("mandelbrot", (re, im))):
            h = d.submit("t", module, [chunk])
            assert len(h.future.result(timeout=300)) == 1
        with d._lock:
            assert d.stats["reconfigurations"] == 3
            assert ("mandelbrot", 1) in d.fabric.cost._est
            assert ("sobel", 1) in d.fabric.cost._est
            # a real wall-time observation, not the clamp floor a bogus
            # penalty subtraction would leave (t_run wraps the run only,
            # so no reconfiguration cost is ever subtracted from it)
            assert d.fabric.cost.est_chunk_ms("mandelbrot", 1) > 1e-2
            assert d.fabric.cost.est_chunk_ms("sobel", 1) > 1e-2
    finally:
        d.shutdown()


# -- daemon over a fabric -----------------------------------------------------

def test_jobhandle_and_scheduler_share_ms_clock():
    """Regression: JobHandle.t_submit was perf_counter() *seconds* while
    the scheduler clock is milliseconds; both now use _now_ms()."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg)
    try:
        rng = np.random.default_rng(2)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        before = _now_ms()
        h = d.submit("t", "mandelbrot", [(re, im)])
        after = _now_ms()
        assert before <= h.t_submit <= after
        assert len(h.future.result(timeout=300)) == 1
        with d._lock:
            req = d.state.requests[h.rid]
            # the scheduler request is stamped with the handle's clock
            assert req.t_submit == h.t_submit
    finally:
        d.shutdown()


def test_multi_shell_daemon_exactly_once():
    """Two live shells (sharing the single CPU device): affinity routes
    jobs, stealing may rebalance, and every chunk resolves exactly once
    with consistent fabric state afterwards."""
    import jax
    devs = jax.devices()
    shells = {"a": Shell(uniform_shell("fa", (1, 1), 1), devs),
              "b": Shell(uniform_shell("fb", (1, 1), 1), devs)}
    reg = default_registry()
    d = Daemon(shells, reg, PolicyConfig())
    try:
        rng = np.random.default_rng(3)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        img = rng.random((1024, 1024)).astype(np.float32)
        h1 = d.submit("heavy", "mandelbrot", [(re, im)] * 4,
                      affinity="a")
        h2 = d.submit("light", "sobel", [(img,)], affinity="b")
        out1 = h1.future.result(timeout=300)
        out2 = h2.future.result(timeout=300)
        assert len(out1) == 4 and len(out2) == 1
        assert all(np.asarray(o).shape == (256, 256) for o in out1)
        assert np.asarray(out2[0]).shape == (1024, 1024)
        with d._lock:
            assert not d._results and not d._handles
            for st in d.fabric.states.values():
                assert not st.alloc.busy and not st.active
            assert all(j.complete for j in d.fabric.jobs.values())
        # exactly-once even if idle shell b stole heavy chunks
        assert d.stats["chunks"] == 5
    finally:
        d.shutdown()
