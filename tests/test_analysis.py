"""schedlint + runtime sanitizer coverage.

Three layers:

  - **corpus**: every seeded-violation fixture under
    tests/fixtures/lint/ is flagged on exactly its `# EXPECT: <checker>`
    lines, and every known-good fixture produces zero findings (no
    false positives);
  - **repo**: `python -m repro.analysis` is clean on the real core —
    the same gate CI runs;
  - **sanitizer**: a silent (touch-less) mutation of tracked state is
    (a) demonstrably a real divergence — the incremental fabric keeps
    treating the shell as a fixpoint while `full_reschedule` places the
    smuggled work — and (b) caught by `REPRO_SANITIZE=1` at the next
    event, while legitimate API-mutating runs stay byte-identical to an
    unsanitized run.

Pure-stdlib: no jax, no hypothesis.
"""
from __future__ import annotations

import pathlib
import re

import pytest

from repro.analysis import analyze
from repro.analysis import sanitizer
from repro.analysis.__main__ import main as schedlint_main
from repro.core import Fabric, PolicyConfig

from golden_traces import build_registry, load_fixture, run_trace, \
    to_jsonable

LINT_DIR = pathlib.Path(__file__).parent / "fixtures" / "lint"
_EXPECT_RE = re.compile(r"#\s*EXPECT:\s*(\w+)")


def _expected(path: pathlib.Path) -> set[tuple[int, str]]:
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        m = _EXPECT_RE.search(line)
        if m:
            out.add((i, m.group(1)))
    return out


# -- corpus -------------------------------------------------------------------

BAD = sorted(LINT_DIR.glob("bad_*.py"))
GOOD = sorted(LINT_DIR.glob("good_*.py"))


def test_corpus_exists():
    assert len(BAD) >= 5 and len(GOOD) >= 3


@pytest.mark.parametrize("path", BAD, ids=lambda p: p.stem)
def test_bad_fixture_fully_flagged(path):
    """Every seeded violation is found — at its exact line, by the
    expected checker — and nothing else in the file is flagged."""
    expected = _expected(path)
    assert expected, f"{path.name} declares no EXPECT markers"
    findings = analyze([str(path)])
    got = {(f.line, f.checker) for f in findings}
    assert got == expected, (
        f"{path.name}: expected {sorted(expected)}, got "
        f"{sorted(got)}:\n" + "\n".join(str(f) for f in findings))


@pytest.mark.parametrize("path", GOOD, ids=lambda p: p.stem)
def test_good_fixture_zero_false_positives(path):
    findings = analyze([str(path)])
    assert not findings, "\n".join(str(f) for f in findings)


def test_pragma_without_reason_is_a_finding(tmp_path):
    p = tmp_path / "lazy_pragma.py"
    p.write_text(
        "SCHEDLINT_SIM = True\n"
        "import time  # schedlint: ok(determinism)\n")
    findings = analyze([str(p)])
    assert any("justification" in f.message for f in findings)


# -- the real repo ------------------------------------------------------------

def test_repo_is_clean():
    assert schedlint_main([]) == 0


def test_core_contract_declarations_present():
    """The checkers only bite if the contracts stay declared."""
    core = pathlib.Path(__file__).parents[1] / "src" / "repro" / "core"
    assert "TRACKED_FIELDS" in (core / "scheduler.py").read_text()
    assert "MEMO_CONTRACTS" in (core / "fabric.py").read_text()
    assert "MEMO_CONTRACTS" in (core / "arrivals.py").read_text()
    assert "CKPT_MUTATORS" in (core / "checkpoint.py").read_text()


# -- runtime sanitizer --------------------------------------------------------

def _small_fabric():
    pol = PolicyConfig(preemptive=True, steal=True,
                       starvation_bound_ms=50.0)
    return Fabric({"a": (2, 1.0), "b": (2, 1.0)}, build_registry(), pol)


def _smuggle_chunk(fab, shell):
    """Mutate tracked state the way a buggy executor would: through
    aliases, bypassing every SchedulerState method and `_touch`."""
    st = fab.states[shell]
    req = next(iter(st.requests.values()))
    req._chunks.append(req.n_chunks)
    req.n_chunks += 1
    st._pending_n += 1


def test_silent_mutation_diverges_incremental_from_full():
    """The failure mode the whole PR exists to prevent, demonstrated:
    after a touch-less mutation the incremental fabric sees a fixpoint
    and schedules nothing, while the reschedule-everything reference
    places the smuggled chunk."""
    outs = {}
    for full in (False, True):
        # single shell, no stealing: the smuggled chunk only exists in
        # the shell's request, so the cross-shell steal path (which
        # maps chunk ids through the fabric's submission map) must not
        # run — the divergence is purely place-locally vs fixpoint.
        # elastic + 4 slots so the smuggled chunk is eligible (chunk 0
        # is still outstanding) and a free range exists for it.
        fab = Fabric({"a": (4, 1.0)}, build_registry(),
                     PolicyConfig(elastic=True))
        fab.submit("t", "batch", 1, now=0.0)
        fab.schedule(0.0)             # place the only chunk
        fab.schedule(0.5)             # settle: drain the dispatch dirty
        _smuggle_chunk(fab, "a")
        fab.full_reschedule = full
        outs[full] = fab.schedule(1.0)
    assert outs[True] and not outs[False], (
        "expected full_reschedule to place the smuggled chunk and the "
        "incremental core to miss it")


def test_sanitizer_catches_silent_mutation(monkeypatch):
    monkeypatch.setattr(sanitizer, "SANITIZE", True)
    fab = _small_fabric()
    fab.submit("t", "batch", 1, now=0.0)
    fab.schedule(0.0)
    _smuggle_chunk(fab, "a")
    with pytest.raises(sanitizer.SanitizerError):
        fab.schedule(1.0)


def test_sanitizer_checks_clean_shells_too(monkeypatch):
    """The elided (clean) shells are exactly the ones a silent mutation
    corrupts — the fabric must check every shell on every event, not
    just the dirty set."""
    monkeypatch.setattr(sanitizer, "SANITIZE", True)
    fab = _small_fabric()
    fab.submit("t", "batch", 1, now=0.0, affinity="a")
    fab.submit("u", "inter", 1, now=0.0, affinity="b")
    fab.schedule(0.0)
    fab.schedule(1.0)                 # both shells now clean
    _smuggle_chunk(fab, "b")          # corrupt a shell not re-dirtied
    with pytest.raises(sanitizer.SanitizerError):
        fab.schedule(2.0)


def test_sanitizer_accepts_legitimate_mutations(monkeypatch):
    """A full feature-dense golden trace under the sanitizer: every
    API-path mutation passes the checks and the result stays
    byte-identical to the committed unsanitized fixture."""
    monkeypatch.setattr(sanitizer, "SANITIZE", True)
    res = run_trace("hetero_steal_ckpt")
    assert to_jsonable(res) == load_fixture("hetero_steal_ckpt")


def test_empty_take_steal_still_touches():
    """Regression for the schedlint mutation finding this PR fixed:
    `steal_pending`/`steal_front` used to touch only `if take` — but
    `_pop_finished` can mutate the tenant queue even when the take is
    empty.  The touch is now unconditional: an empty take bumps the
    version and re-dirties the shell (a no-op reschedule), never a
    silent skip."""
    fab = _small_fabric()
    fab.submit("t", "batch", 2, now=0.0)
    fab.schedule(0.0)
    st = fab.states[next(n for n, s in fab.states.items() if s.requests)]
    rid = next(iter(st.requests))
    dirtied = []
    st.on_change, prev = (lambda: dirtied.append(1)), st.on_change
    try:
        v0 = st._version
        assert st.steal_pending(rid, 0) == []
        assert st._version > v0
        assert dirtied
    finally:
        st.on_change = prev
