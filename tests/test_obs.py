"""Flight recorder (PR 9, repro.obs): byte-identity, pairing,
conservation, sampling, export, and the Daemon.metrics surface.

The contract under test has two halves.  Detached (`fabric.obs is
None`, the default) the observability subsystem must be invisible:
every golden fixture reproduces byte for byte.  Attached, it must be
*read-only*: scheduling outputs are unchanged to the byte, while the
trace events, counters, and samples it collects satisfy the
conservation identities they were built around (every steal probe is
exactly one hit or miss, every submit exactly one verdict, every
started chunk exactly one completion or preemption).
"""
from __future__ import annotations

import json

import pytest

from golden_traces import TRACES, load_fixture, run_trace, to_jsonable
from repro.obs import (COUNTER_NAMES, CounterSampler, FlightRecorder,
                       Tracer, chrome_trace, export_chrome_trace)
from repro.obs import trace as tr


# -- tracing off: byte-identical goldens --------------------------------------

@pytest.mark.parametrize("name", sorted(TRACES))
def test_tracing_off_goldens_byte_identical(name):
    """No recorder attached -> the serialised SimResult is exactly the
    pre-observability fixture (the `metrics` field vanishes)."""
    res = run_trace(name)
    assert res.metrics == {}
    assert to_jsonable(res) == load_fixture(name)


@pytest.mark.parametrize("name", sorted(TRACES))
def test_recorder_attached_outputs_unchanged(name):
    """Full tracing + counters + sampling on -> every scheduling output
    still matches the fixture byte for byte; only `metrics` appears."""
    rec = FlightRecorder(trace=True, sample_every_ms=10.0)
    res = run_trace(name, obs=rec)
    assert res.metrics            # non-empty: the recorder did attach
    d = to_jsonable(res)
    d.pop("metrics")
    assert d == load_fixture(name)


# -- span pairing -------------------------------------------------------------

def test_trace_events_pair_with_timeline_spans():
    rec = FlightRecorder(trace=True)
    res = run_trace("hetero_steal_ckpt", obs=rec)
    events = list(rec.tracer.events)
    assert rec.tracer.dropped == 0
    starts = [e for e in events if e.kind == tr.CHUNK_START]
    comps = [e for e in events if e.kind == tr.CHUNK_COMPLETE]
    pres = [e for e in events if e.kind == tr.PREEMPT]
    assert len(comps) == len(res.timeline)
    assert len(pres) == len(res.preempted_spans)
    assert len(starts) == len(comps) + len(pres)
    # every completion/preemption closes a distinct started aid
    open_aids = {e.aid for e in starts}
    assert len(open_aids) == len(starts)
    for e in comps + pres:
        assert e.aid in open_aids
    # ...and spans close at the span end times the SimResult reports
    comp_ts = sorted(e.t_ms for e in comps)
    assert comp_ts == sorted(t1 for *_x, t0, t1 in res.timeline) \
        or len(comp_ts) == len(res.timeline)


def test_event_timestamps_monotone_and_typed():
    rec = FlightRecorder(trace=True)
    run_trace("contracts_full", obs=rec)
    events = list(rec.tracer.events)
    assert all(a.t_ms <= b.t_ms for a, b in zip(events, events[1:]))
    assert {e.kind for e in events} <= set(tr.KINDS)


# -- counter conservation -----------------------------------------------------

@pytest.mark.parametrize("name", ["contracts_full", "hetero_steal_ckpt"])
def test_counter_conservation(name):
    rec = FlightRecorder(trace=False)       # counters alone still work
    res = run_trace(name, obs=rec)
    c = res.metrics["counters"]
    assert set(c) == set(COUNTER_NAMES)
    assert c["submitted"] == c["admitted"] + c["degraded"] + c["rejected"]
    assert c["steal_probes"] == c["steal_hits"] + c["steal_misses"]
    assert c["chunks_started"] == len(res.timeline) \
        + len(res.preempted_spans)
    assert c["chunks_completed"] == len(res.timeline)
    assert c["chunks_preempted"] == len(res.preempted_spans)
    assert c["stolen_chunks"] == res.stolen_chunks
    assert c["ckpt_migrations"] == res.ckpt_migrations
    # every restore consumes a record created at some eviction; the
    # recorder counts save *events* (CheckpointManager's own `saves`
    # skips re-recorded prior contexts, so it can undercount them)
    assert c["ckpt_saves"] >= res.ckpt_restores
    if res.slo:
        tot = res.metrics["admission"]
        assert c["submitted"] == tot["submitted"]
        assert c["degraded"] == tot["degraded"]
        assert c["rejected"] == tot["rejected"]


def test_tenant_service_accounting_positive():
    rec = FlightRecorder(trace=False)
    res = run_trace("hetero_steal_ckpt", obs=rec)
    svc = res.metrics["tenant_service_ms"]
    assert svc and all(v > 0 for v in svc.values())
    tenants = {m["tenant"] for m in res.request_meta.values()}
    assert set(svc) <= tenants


def test_self_profile_rates():
    rec = FlightRecorder(trace=False)
    res = run_trace("hetero_steal_ckpt", obs=rec)
    prof = res.metrics["profile"]
    assert prof["passes"] > 0
    assert prof["shells_visited"] + prof["shells_elided"] \
        == 3 * prof["passes"]               # 3-shell trace
    assert 0.0 <= prof["elision_rate"] <= 1.0
    assert 0.0 <= prof["backlog_hit_rate"] <= 1.0
    assert 0.0 <= prof["steal_cache_hit_rate"] <= 1.0
    assert prof["backlog_hits"] + prof["backlog_misses"] > 0


# -- sampler ------------------------------------------------------------------

def test_sampler_history_monotone_and_bounded():
    rec = FlightRecorder(trace=False, sample_every_ms=5.0, history_max=64)
    res = run_trace("hetero_steal_ckpt", obs=rec)
    samples = res.metrics["samples"]
    assert 0 < len(samples) <= 64
    ts = [s["t_ms"] for s in samples]
    assert ts == sorted(ts)
    # at most one sample per 5 ms due-window (a late sample and the
    # next on-time one may be close together, so no minimum gap —
    # but the count over the span is bounded by the window count)
    assert len(ts) <= (ts[-1] - ts[0]) / 5.0 + 1 + 1e-9
    for s in samples:
        assert 0.0 <= s["occupancy"] <= 1.0
        assert s["pending_chunks"] >= 0
    # counters in samples are monotone running totals
    for a, b in zip(samples, samples[1:]):
        for k in COUNTER_NAMES:
            assert b["counters"][k] >= a["counters"][k]


def test_sampler_skips_missed_windows_without_catchup():
    s = CounterSampler(10.0, history_max=8)
    reads = []
    assert s.maybe_sample(0.0, lambda: dict(reads.append(1) or {}))
    assert not s.maybe_sample(3.0, lambda: {})
    # a 47 ms quiet stretch: one sample now, next due at 50 (not 20)
    assert s.maybe_sample(47.0, lambda: {})
    assert not s.maybe_sample(49.0, lambda: {})
    assert s.maybe_sample(50.0, lambda: {})
    assert [row["t_ms"] for row in s.history] == [0.0, 47.0, 50.0]
    assert len(reads) == 1                  # gauges read only when due


def test_tracer_ring_buffer_counts_drops():
    t = Tracer(max_events=4)
    for i in range(7):
        t.emit(float(i), tr.SUBMIT, rid=i)
    assert len(t) == 4
    assert t.dropped == 3
    # bounded ring keeps the newest events, counts the evicted oldest
    assert [e.rid for e in t.events] == [3, 4, 5, 6]


# -- Chrome trace export ------------------------------------------------------

def test_chrome_trace_export(tmp_path):
    rec = FlightRecorder(trace=True)
    res = run_trace("hetero_steal_ckpt", obs=rec)
    path = tmp_path / "trace.json"
    doc = export_chrome_trace(rec.tracer, path)
    with open(path) as f:
        assert json.load(f) == json.loads(json.dumps(doc))
    evs = doc["traceEvents"]
    names = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"big", "fast", "slow", "fabric"} <= names
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == len(res.timeline) + len(res.preempted_spans)
    assert sum(1 for e in xs if e["args"].get("preempted")) \
        == len(res.preempted_spans)
    assert doc["otherData"]["dropped_events"] == 0
    # ts/dur are microseconds of sim-ms: spot-check one complete span
    for e in xs:
        assert e["dur"] >= 0


def test_chrome_trace_accepts_plain_event_list():
    t = Tracer()
    t.emit(1.0, tr.CHUNK_START, shell="s0", aid=7)
    t.emit(3.5, tr.CHUNK_COMPLETE, shell="s0", aid=7)
    doc = chrome_trace(list(t.events))
    (x,) = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert x["ts"] == 1000.0 and x["dur"] == 2500.0


# -- attachment rules ---------------------------------------------------------

def test_recorder_attaches_once():
    from golden_traces import build_registry
    from repro.core import Fabric, PolicyConfig
    reg = build_registry()
    fab = Fabric({"s0": 2}, reg, PolicyConfig())
    fab2 = Fabric({"s0": 2}, reg, PolicyConfig())
    rec = FlightRecorder()
    rec.attach(fab)
    with pytest.raises(ValueError):
        rec.attach(fab2)                    # recorder is single-fabric
    with pytest.raises(ValueError):
        FlightRecorder().attach(fab)        # fabric already recorded


# -- Daemon.metrics surface ---------------------------------------------------

def test_daemon_metrics_and_aliases():
    import numpy as np
    from repro.core import Daemon, Shell, default_registry, uniform_shell
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    reg.register_shell(spec)
    rec = FlightRecorder(trace=True, sample_every_ms=50.0)
    d = Daemon(Shell(spec), reg, obs=rec)
    try:
        rng = np.random.default_rng(0)
        re = rng.uniform(-2, 1, (64, 64)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (64, 64)).astype(np.float32)
        h = d.submit("alice", "mandelbrot", [(re, im)] * 2)
        h.future.result(timeout=120)
        m = d.metrics
        assert {"daemon", "ckpt", "slo", "reserve_history", "obs"} \
            <= set(m)
        # the legacy properties are thin aliases over the same payload
        assert d.ckpt_stats == m["ckpt"]
        assert d.slo_stats == m["slo"]
        assert d.reserve_history == m["reserve_history"]
        c = m["obs"]["counters"]
        assert c["jobs_dispatched"] >= 1
        assert c["chunks_completed"] >= 2
        assert c["submitted"] == c["admitted"] + c["degraded"] \
            + c["rejected"]
        assert any(e.kind == tr.CHUNK_COMPLETE
                   for e in rec.tracer.events)
    finally:
        d.shutdown()
