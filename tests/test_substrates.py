"""Substrate tests: optimizer, data pipeline, checkpointing, compression,
and the end-to-end training driver (fault injection, restart, elastic
re-partition)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, Pipeline, SyntheticSource
from repro.optim import adamw, grad_compress as gc


# -- optimizer ----------------------------------------------------------------


def test_adamw_matches_reference_numpy():
    cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, schedule="constant",
                            weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]])}
    grads = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]])}
    opt = adamw.init(params)
    p1, opt, _ = adamw.update(cfg, grads, opt, params)
    # hand-computed first Adam step: update = lr * g_hat where g/|g| -> lr
    g = np.array([[0.1, 0.2], [-0.3, 0.4]])
    m = 0.1 * g
    v = 0.05 * g ** 2
    upd = (m / 0.1) / (np.sqrt(v / 0.05) + 1e-8)
    want = np.array([[1.0, -2.0], [0.5, 3.0]]) - 1e-2 * upd
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-5)


def test_adamw_decreases_loss_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, warmup_steps=0, schedule="constant",
                            weight_decay=0.0)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.update(cfg, g, opt, params)
    assert loss(params) < 1e-2


def test_lr_schedule_shapes():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.lr_at(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0                # warmup
    assert lrs[50] > lrs[99]                     # decay
    assert lrs[99] >= 0.1 * 0.99                 # floor


# -- gradient compression -------------------------------------------------------


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_int8_quantization_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 512)) * 3.0
    q, s = gc.quantize_int8(x)
    deq = gc.dequantize_int8(q, s)
    # error bounded by half a quantization step per row
    step = np.asarray(s)[..., 0] / 1.0
    err = np.abs(np.asarray(x) - np.asarray(deq)).max(axis=-1)
    assert (err <= step * 0.5 + 1e-6).all()


def test_error_feedback_preserves_signal():
    """Sum of compressed grads (with EF) converges to sum of true grads."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (4, 512)) * 0.01
    ef = {"g": jnp.zeros((4, 512))}
    acc = jnp.zeros((4, 512))
    for _ in range(50):
        comp, ef_new = gc.compress_grads({"g": g_true}, ef)
        ef = ef_new
        acc = acc + comp["g"]
    want = 50 * g_true
    # relative error shrinks well below a single step's quantization error
    rel = jnp.linalg.norm(acc - want) / jnp.linalg.norm(want)
    assert rel < 0.01, rel


def test_compression_ratio():
    grads = {"w": jnp.zeros((1024, 1024)), "b": jnp.zeros((8,))}
    r = gc.compression_ratio(grads)
    assert 0.25 < r < 0.27       # int8 + per-row scales ~ 0.254


# -- data pipeline ---------------------------------------------------------------


def test_pipeline_deterministic_and_restart_aligned():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=4, seed=7)
    p1 = Pipeline(cfg)
    first = [next(p1) for _ in range(5)]
    p1.close()
    # restart from step 3: identical stream
    p2 = Pipeline(cfg, start_step=3)
    s3, b3 = next(p2)
    p2.close()
    assert s3 == 3
    np.testing.assert_array_equal(b3["tokens"], first[3][1]["tokens"])
    assert (b3["tokens"] < cfg.vocab).all() and (b3["tokens"] >= 0).all()


def test_synthetic_source_step_independent():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2, seed=1)
    s = SyntheticSource(cfg)
    np.testing.assert_array_equal(s.batch(10), s.batch(10))
    assert not np.array_equal(s.batch(10), s.batch(11))


# -- checkpointing ----------------------------------------------------------------


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
             "count": jnp.int32(7)}
    for step in (1, 2, 3):
        mgr.save(step, state, blocking=True)
    mgr.wait()
    assert mgr.steps() == [2, 3], "retention keeps last 2"
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    got = mgr.restore(3, like)
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.asarray(state["a"]))
    assert got["nested"]["b"].dtype == jnp.bfloat16
    assert int(got["count"]) == 7


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Save under one sharding, restore under another (1-device meshes with
    different PartitionSpecs stand in for a re-meshed cluster)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(tmp_path)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    mgr.save(5, state, blocking=True)
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    sh = {"w": NamedSharding(mesh, P("model", "data"))}
    got = mgr.restore(5, like, sh)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(state["w"]))
    assert got["w"].sharding.spec == P("model", "data")


# -- end-to-end training driver ---------------------------------------------------


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import TrainRun, train
    hist = train(TrainRun(arch="llama3.2-3b", steps=25, global_batch=8,
                          seq_len=32, lr=3e-3, log_every=100),
                 log=lambda *a: None)
    losses = dict(hist["loss"])
    assert losses[0] > losses[24], f"no learning: {hist['loss']}"


def test_train_fault_injection_restart(tmp_path):
    from repro.launch.train import TrainRun, train
    hist = train(TrainRun(arch="llama3.2-3b", steps=20, global_batch=4,
                          seq_len=32, ckpt_dir=str(tmp_path / "ck"),
                          ckpt_every=5, fail_at_step=12,
                          log_every=100), log=lambda *a: None)
    assert hist["restarts"] == 1
    assert hist["final_step"] == 20


def test_train_elastic_repartition(tmp_path):
    from repro.launch.train import TrainRun, train
    hist = train(TrainRun(arch="granite-3-8b", steps=16, global_batch=4,
                          seq_len=32, ckpt_dir=str(tmp_path / "ck"),
                          elastic_switch_step=8, log_every=100),
                 log=lambda *a: None)
    assert hist["elastic_switches"] == 1
    assert hist["final_step"] == 16


def test_train_grad_compress_runs(tmp_path):
    from repro.launch.train import TrainRun, train
    hist = train(TrainRun(arch="llama3.2-3b", steps=12, global_batch=4,
                          seq_len=32, lr=3e-3, grad_compress=True,
                          log_every=100), log=lambda *a: None)
    losses = dict(hist["loss"])
    assert losses[11] < losses[0]
