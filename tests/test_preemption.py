"""Preemptive priority scheduling: deterministic property tests.

Covers the THEMIS-style extensions to the resource-elastic core:
  - every preempted chunk is requeued and completes exactly once;
  - slot capacity is respected even counting truncated (evicted) spans;
  - cooperative policy never preempts;
  - aging bounds starvation of low-priority tenants under a saturating
    high-priority stream;
  - equal-priority ties break earliest-deadline-first;
  - elastic+preemptive dominates fixed scheduling on deadline-miss rate
    and high-priority tail latency;
  - the live daemon stays consistent (futures, results, allocator) under
    a preemptive policy.
"""
from __future__ import annotations

from collections import Counter

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import Daemon, ImplAlt, ModuleDescriptor, PolicyConfig, \
    Registry, Shell, SimJob, default_registry, simulate, uniform_shell


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0),
               ImplAlt("x4", 4, 12.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.4))))
    return reg


jobs_strategy = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, 15.0, 60.0])),
    min_size=1, max_size=18)


def _check_spans_consistent(res, n_slots: int) -> None:
    """Capacity + no double-booking over completed AND evicted spans."""
    spans = list(res.timeline) + list(res.preempted_spans)
    events = []
    for t0, t1, (s, size), _ in spans:
        events += [(t0, size), (t1, -size)]
    busy = 0
    # at equal timestamps, completions (-size) precede starts (+size)
    for _, d in sorted(events, key=lambda e: (e[0], e[1])):
        busy += d
        assert busy <= n_slots
    per_slot: dict[int, list] = {}
    for t0, t1, (s, size), _ in spans:
        for i in range(s, s + size):
            per_slot.setdefault(i, []).append((t0, t1))
    for slot_spans in per_slot.values():
        slot_spans.sort()
        for (a0, a1), (b0, b1) in zip(slot_spans, slot_spans[1:]):
            assert b0 >= a1 - 1e-9, "slot double-booked"


@given(jobs_strategy, st.sampled_from([1, 2, 4]))
@settings(max_examples=80, deadline=None)
def test_preempted_chunks_complete_exactly_once(raw, n_slots):
    jobs = [SimJob(t, u, m, c, priority=p, deadline_ms=d)
            for t, u, m, c, p, d in raw]
    res = simulate(_registry(), n_slots, jobs,
                   PolicyConfig(preemptive=True))
    # exactly-once: completed timeline entries per request == its chunks,
    # regardless of how many evictions the request suffered
    done = Counter(rid for *_, rid in res.timeline)
    for rid, meta in res.request_meta.items():
        assert done[rid] == meta["n_chunks"], \
            f"rid {rid}: {done[rid]} completions != {meta['n_chunks']}"
    assert res.preemptions == len(res.preempted_spans)
    _check_spans_consistent(res, n_slots)


@given(jobs_strategy, st.sampled_from([1, 2, 4]))
@settings(max_examples=40, deadline=None)
def test_cooperative_policy_never_preempts(raw, n_slots):
    jobs = [SimJob(t, u, m, c, priority=p, deadline_ms=d)
            for t, u, m, c, p, d in raw]
    res = simulate(_registry(), n_slots, jobs,
                   PolicyConfig(preemptive=False))
    assert res.preemptions == 0 and not res.preempted_spans


def test_high_priority_preempts_resident_chunk():
    """A high-priority arrival evicts the running low-priority chunk and
    meets its deadline; the victim chunk re-runs and completes."""
    jobs = [SimJob(0.0, "lo", "batch", 2),
            SimJob(10.0, "hi", "inter", 1, priority=2, deadline_ms=20.0)]
    res = simulate(_registry(), 1, jobs, PolicyConfig(preemptive=True))
    assert res.preemptions == 1
    assert res.deadline_misses() == 0
    hi_rid = next(r for r, m in res.request_meta.items()
                  if m["priority"] == 2)
    assert res.request_latency[hi_rid] < 15.0
    done = Counter(rid for *_, rid in res.timeline)
    assert done == {0: 2, 1: 1}
    # without preemption the same trace misses the deadline
    coop = simulate(_registry(), 1, jobs, PolicyConfig(preemptive=False))
    assert coop.deadline_misses() == 1


def test_starvation_bound_protects_low_priority():
    """Aging promotes a starved request one level per starvation_bound_ms,
    so a saturating priority-3 stream delays a priority-0 request by at
    most ~3 bounds before it gets served."""
    bound = 100.0
    jobs = [SimJob(0.0, "lo", "batch", 1)]
    jobs += [SimJob(4.0 * i, "hi", "inter", 1, priority=3)
             for i in range(150)]          # saturates the slot for 600 ms
    res = simulate(_registry(), 1, jobs,
                   PolicyConfig(preemptive=True,
                                starvation_bound_ms=bound))
    lo_rid = next(r for r, m in res.request_meta.items()
                  if m["tenant"] == "lo")
    # served once aged 3 levels (300 ms) + current chunk + its own 40 ms
    assert res.request_latency[lo_rid] <= 3 * bound + 50.0, \
        f"starved: {res.request_latency[lo_rid]}"
    # and the high-priority stream was not starved either
    assert res.p95_latency(priority=3) <= 60.0


def test_aging_resets_while_served():
    """Aging measures queueing delay, not lifetime: a batch request that
    has been continuously served for many bounds must not out-rank (or
    resist preemption by) a fresh high-priority arrival."""
    jobs = [SimJob(0.0, "lo", "batch", 30)]           # served nonstop
    jobs += [SimJob(900.0, "hi", "inter", 1, priority=2,
                    deadline_ms=20.0)]
    res = simulate(_registry(), 1, jobs,
                   PolicyConfig(preemptive=True,
                                starvation_bound_ms=100.0))
    # lifetime aging would put the batch request at eff 9 by t=900 and
    # block the eviction; queueing-delay aging keeps it at ~0
    assert res.preemptions == 1
    assert res.deadline_misses() == 0


def test_long_running_chunk_gains_no_preemption_immunity():
    """Regression: a chunk defends at its placement-time priority — a
    long low-priority chunk must stay evictable however long it has been
    running (its 'aging' while served is service time, not starvation)."""
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="long", entrypoint="x:y", impls=(ImplAlt("x1", 1, 1000.0),)))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y", impls=(ImplAlt("x1", 1, 4.0),)))
    jobs = [SimJob(0.0, "lo", "long", 1),
            SimJob(450.0, "hi", "inter", 1, priority=3, deadline_ms=25.0)]
    res = simulate(reg, 1, jobs, PolicyConfig(preemptive=True))
    assert res.preemptions == 1
    assert res.deadline_misses() == 0


def test_urgent_request_overtakes_same_tenant_batch():
    """Per-request priority holds within one tenant's own queue: an
    urgent submit is served before the tenant's earlier batch request."""
    jobs = [SimJob(0.0, "a", "batch", 6),
            SimJob(1.0, "a", "inter", 1, priority=5, deadline_ms=60.0)]
    res = simulate(_registry(), 1, jobs, PolicyConfig(preemptive=True))
    urgent = next(r for r, m in res.request_meta.items()
                  if m["priority"] == 5)
    assert res.deadline_misses() == 0
    assert res.request_latency[urgent] < 60.0, \
        "urgent request FIFO-blocked behind its own tenant's batch work"


def test_equal_priority_ties_break_edf():
    """Among equal-priority queued requests the earliest absolute deadline
    is served first; deadline-less requests go last."""
    jobs = [SimJob(0.0, "a", "batch", 2),                       # rid 0
            SimJob(1.0, "b", "batch", 1, deadline_ms=100.0),    # rid 1
            SimJob(2.0, "c", "batch", 1, deadline_ms=30.0)]     # rid 2
    res = simulate(_registry(), 1, jobs, PolicyConfig())
    order = [rid for *_, rid in sorted(res.timeline)]
    assert order == [0, 2, 1, 0], order


def test_preemptive_elastic_dominates_fixed_on_deadlines():
    """Acceptance: elastic+preemptive beats fixed run-to-completion on
    deadline-miss rate and high-priority p95 latency."""
    import random
    rng = random.Random(0)
    jobs = []
    t = 0.0
    for i in range(6):                       # two batch tenants, heavy load
        jobs.append(SimJob(t, f"b{i % 2}", "batch", 4))
        t += rng.uniform(5.0, 20.0)
    t = 3.0
    for i in range(25):                      # interactive stream, deadlines
        jobs.append(SimJob(t, "hi", "inter", 1, priority=2,
                           deadline_ms=25.0))
        t += rng.uniform(8.0, 20.0)
    pre = simulate(_registry(), 4, jobs,
                   PolicyConfig(elastic=True, preemptive=True))
    fix = simulate(_registry(), 4, jobs, PolicyConfig(elastic=False))
    assert pre.deadline_miss_rate <= fix.deadline_miss_rate
    assert pre.p95_latency(priority=2) <= fix.p95_latency(priority=2)
    assert pre.deadline_miss_rate < 0.2, pre.deadline_miss_rate


def test_preempt_margin_zero_terminates():
    """Regression: margin<=0 must not let equal-priority requests evict
    each other endlessly inside one schedule() pass (clamped to 1)."""
    jobs = [SimJob(0.0, "u0", "batch", 3), SimJob(0.0, "u1", "batch", 3)]
    res = simulate(_registry(), 1, jobs,
                   PolicyConfig(preemptive=True, preempt_margin=0))
    assert res.preemptions == 0      # equal priority -> margin 1 -> no evict


def test_preempting_last_chunk_of_aborted_request_unblocks_tenant():
    """Regression: when a request is aborted (chunk error) and its last
    in-flight chunk is then *preempted* rather than completed, the dead
    request must still be popped from its tenant queue."""
    from repro.core import SchedulerState
    reg = _registry()
    state = SchedulerState(2, reg, PolicyConfig(preemptive=True))
    req = state.submit("t", "inter", 2, now=0.0)
    issued = state.schedule(now=0.0)          # both chunks replicate
    assert len(issued) == 2
    assert state.complete(issued[1], now=1.0)
    state.abort(req.rid)                      # chunk error; chunk0 in flight
    assert not req.finished
    # high-priority arrival evicts the aborted request's remaining chunk
    state.submit("hi", "batch", 4, now=2.0, priority=5)
    state.schedule(now=2.0)
    assert any(v.rid == req.rid for v in state.drain_preempted())
    assert req.finished, "aborted request never drained"
    # the tenant is unblocked: its next request gets scheduled
    nxt = state.submit("t", "inter", 1, now=3.0, priority=6)
    assigned = state.schedule(now=3.0)
    assert any(a.rid == nxt.rid for a in assigned), \
        "tenant queue still head-of-line blocked by a dead request"


def test_preemption_evicts_only_the_window_it_uses():
    """Regression: eviction must be scoped to one placeable window — an
    innocent low-priority chunk whose slot can't help the placement (its
    window is blocked by a non-evictable neighbour) keeps running."""
    from repro.core import SchedulerState
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="one", entrypoint="x:y", impls=(ImplAlt("x1", 1, 10.0),)))
    reg.register_module(ModuleDescriptor(
        name="two", entrypoint="x:y", impls=(ImplAlt("x2", 2, 10.0),)))
    state = SchedulerState(4, reg, PolicyConfig(preemptive=True))
    state.submit("lo", "one", 1, now=0.0, priority=0)       # -> slot 0
    (a_lo,) = state.schedule(now=0.0)
    state.submit("res", "one", 1, now=0.0, priority=5)      # -> slot 1
    (a_res,) = state.schedule(now=0.0)
    state.submit("y", "two", 1, now=0.0, priority=1)        # -> slots 2-3
    (a_y,) = state.schedule(now=0.0)
    assert (a_lo.rng.start, a_res.rng.start, a_y.rng.start) == (0, 1, 2)
    # priority-5 arrival needs 2 slots: window [0,1] is blocked by the
    # non-evictable priority-5 resident, so only window [2,3] is usable
    pre = state.submit("pre", "two", 1, now=0.0, priority=5)
    placed = state.schedule(now=0.0)
    victims = state.drain_preempted()
    assert [v.aid for v in victims] == [a_y.aid], \
        "evicted an assignment outside the placed window"
    assert a_lo.aid in state.active and a_res.aid in state.active
    assert any(a.rid == pre.rid and a.rng.start == 2 for a in placed)


def test_daemon_releases_payloads_after_completion():
    """Regression: a long-running daemon must not retain every request's
    input arrays after the request resolves."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg)
    try:
        rng = np.random.default_rng(1)
        re_ = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im_ = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        h = d.submit("alice", "mandelbrot", [(re_, im_)] * 2)
        assert len(h.future.result(timeout=300)) == 2
        with d._lock:
            assert d.state.requests[h.rid].payloads is None
    finally:
        d.shutdown()


def test_daemon_finalizes_request_drained_by_preemption():
    """Regression: a failed request whose last in-flight chunk is evicted
    (so it drains through _preempt_for, never through complete()) must
    still release its handle and payload arrays."""
    from concurrent.futures import Future
    from repro.core import JobHandle
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg, PolicyConfig(preemptive=True))
    try:
        with d._lock:
            # drive the scheduler core to the exact state: 2-chunk request
            # with chunk0 in flight, aborted after a chunk error...
            req = d.state.submit("t", "mandelbrot", 2,
                                 payloads=[object(), object()], now=0.0)
            d._results[req.rid] = [None, None]
            d._handles[req.rid] = JobHandle(req.rid, Future(), 0.0)
            (a0,) = d.state.schedule(now=0.0)
            d.state.abort(req.rid)
            assert not req.finished
            # ...then a high-priority arrival evicts the in-flight chunk
            d.state.submit("hi", "mandelbrot", 1, now=1.0, priority=5)
            d.state.schedule(now=1.0)
            d._handle_preempted_locked()      # what _loop runs after schedule
            assert req.finished
            assert req.rid not in d._handles, "leaked JobHandle"
            assert req.rid not in d._results, "leaked results buffer"
            assert req.payloads is None, "leaked payload arrays"
            assert a0.aid in d._cancelled
    finally:
        d.shutdown()


def test_daemon_consistent_under_preemptive_policy():
    """Live executor: a preemptive policy keeps futures/results/allocator
    consistent — every chunk of every request resolves exactly once even
    when low-priority assignments are cancelled and requeued."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    reg.register_shell(spec)
    d = Daemon(Shell(spec), reg,
               PolicyConfig(preemptive=True, reconfig_penalty_ms=0.1))
    try:
        rng = np.random.default_rng(0)
        re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
        im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
        img = rng.random((1024, 1024)).astype(np.float32)
        lo = d.submit("lo", "mandelbrot", [(re, im)] * 3, priority=0)
        hi = d.submit("hi", "sobel", [(img,)], priority=5,
                      deadline_ms=50.0)
        lo_out = lo.future.result(timeout=300)
        hi_out = hi.future.result(timeout=300)
        assert len(lo_out) == 3 and len(hi_out) == 1
        assert all(np.asarray(o).shape == (256, 256) for o in lo_out)
        assert np.asarray(hi_out[0]).shape == (1024, 1024)
        with d._lock:
            assert not d._results and not d._handles
            assert not d.state.alloc.busy and not d.state.active
            assert all(r.complete for r in d.state.requests.values())
        # exactly-once accounting: discarded/cancelled runs don't count
        assert d.stats["chunks"] == 4
    finally:
        d.shutdown()
