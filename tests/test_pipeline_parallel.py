"""GPipe-over-pod correctness: pipelined == sequential (subprocess with a
(pod=2, data=2) mesh)."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding.pipeline_parallel import gpipe_apply, split_stages

mesh = jax.make_mesh((2, 2), ("pod", "data"))
key = jax.random.PRNGKey(0)
L, D, B = 4, 16, 8

w = jax.random.normal(key, (L, D, D)) * 0.3
b = jax.random.normal(jax.random.fold_in(key, 1), (L, D)) * 0.1
x = jax.random.normal(jax.random.fold_in(key, 2), (B, D))

def layer(wl, bl, h):
    return jnp.tanh(h @ wl + bl)

# sequential reference
ref = x
for i in range(L):
    ref = layer(w[i], b[i], ref)

# pipelined: 2 stages x 2 layers each
def stage_fn(params, h):
    ws, bs = params
    for i in range(ws.shape[0]):
        h = layer(ws[i], bs[i], h)
    return h

stage_params = split_stages((w, b), 2)
for n_micro in (2, 4, 8):
    with jax.set_mesh(mesh):
        got = jax.jit(lambda p, xx: gpipe_apply(
            stage_fn, p, xx, mesh=mesh, axis="pod",
            n_micro=n_micro))(stage_params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_gpipe_matches_sequential():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CODE], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-2500:]
    assert "PIPELINE_OK" in out.stdout
