"""Resource-elastic scheduling policy + simulator properties.

Validates the paper's section 4.4 claims structurally:
  - every submitted chunk completes exactly once (simulator assertion);
  - round-robin fairness across tenants;
  - replication uses free slots (single-tenant speedup, Fig 19-21);
  - elastic scheduling beats fixed scheduling on utilization/makespan
    for replicable workloads (Fig 15);
  - resident-module reuse avoids reconfigurations (section 4.4.3).
"""
from __future__ import annotations

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import ImplAlt, ModuleDescriptor, PolicyConfig, Registry, \
    SimJob, simulate


def _registry(perfect_scaling: bool = True) -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="app", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 10.0),
               ImplAlt("x2", 2, 5.0 if perfect_scaling else 8.0),
               ImplAlt("x4", 4, 2.5 if perfect_scaling else 7.0))))
    reg.register_module(ModuleDescriptor(
        name="small", entrypoint="x:y", impls=(ImplAlt("x1", 1, 4.0),)))
    return reg


jobs_strategy = st.lists(
    st.tuples(st.floats(0, 100), st.sampled_from(["u0", "u1", "u2"]),
              st.sampled_from(["app", "small"]), st.integers(1, 9)),
    min_size=1, max_size=25)


@given(jobs_strategy, st.booleans(), st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=120, deadline=None)
def test_all_chunks_complete_and_capacity_respected(raw, elastic, n_slots):
    jobs = [SimJob(t, u, m, c) for t, u, m, c in raw]
    res = simulate(_registry(), n_slots, jobs,
                   PolicyConfig(elastic=elastic))
    # capacity: no more than n_slots busy at any instant
    events = []
    for t0, t1, (s, size), _ in res.timeline:
        events += [(t0, size), (t1, -size)]
    busy = 0
    # at equal timestamps, completions (-size) precede starts (+size)
    for _, d in sorted(events, key=lambda e: (e[0], e[1])):
        busy += d
        assert busy <= n_slots
    # slot ranges never overlap in time
    per_slot: dict[int, list] = {}
    for t0, t1, (s, size), _ in res.timeline:
        for i in range(s, s + size):
            per_slot.setdefault(i, []).append((t0, t1))
    for spans in per_slot.values():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-9, "slot double-booked"


def test_single_tenant_replication_scales():
    """Fig 19-21: one tenant, many chunks -> near-linear with slots."""
    reg = _registry()
    lat = {}
    for n_slots in (1, 2, 4):
        jobs = [SimJob(0.0, "u0", "small", 8)]
        res = simulate(reg, n_slots, jobs)
        lat[n_slots] = res.makespan
    assert lat[2] < 0.62 * lat[1]
    assert lat[4] <= 0.36 * lat[1]  # reconfig overhead bounds perfect scaling


def test_replacement_uses_bigger_impl_when_idle():
    """DCT-style super-linear case: 1 chunk, 4 slots free -> x4 impl."""
    reg = _registry()
    res = simulate(reg, 4, [SimJob(0.0, "u0", "app", 1)])
    (t0, t1, (s, size), _), = res.timeline
    assert size == 4, "idle machine should host the biggest alternative"


def test_elastic_beats_fixed_on_replicable_load():
    """Fig 15: elastic vs standard fixed-module scheduling."""
    reg = _registry()
    jobs = [SimJob(0.0, "u0", "app", 6), SimJob(0.0, "u1", "app", 2),
            SimJob(30.0, "u2", "app", 4)]
    el = simulate(reg, 4, jobs, PolicyConfig(elastic=True))
    fx = simulate(reg, 4, jobs, PolicyConfig(elastic=False))
    assert el.makespan <= fx.makespan
    assert el.utilization >= fx.utilization - 1e-9


def test_round_robin_fairness():
    """Two tenants submitting together interleave at request granularity."""
    reg = _registry()
    jobs = [SimJob(0.0, "u0", "small", 4), SimJob(0.0, "u1", "small", 4)]
    res = simulate(reg, 1, jobs, PolicyConfig(upsize_when_idle=False))
    order = [rid for *_, rid in sorted(res.timeline)]
    # strict alternation on a single slot
    assert order == [0, 1, 0, 1, 0, 1, 0, 1]


def test_reuse_avoids_reconfiguration():
    reg = _registry()
    jobs = [SimJob(0.0, "u0", "small", 3), SimJob(50.0, "u1", "small", 3)]
    res = simulate(reg, 1, jobs)
    assert res.reconfigurations == 1, \
        "same module back-to-back must not reconfigure"


def test_multi_tenant_dynamic_reallocation():
    """Fig 22: after one tenant drains, the other's chunks spread out."""
    reg = _registry()
    jobs = [SimJob(0.0, "u0", "app", 8), SimJob(0.0, "u1", "app", 1)]
    res = simulate(reg, 4, jobs)
    widths_late = [size for t0, _, (s, size), rid in res.timeline
                   if t0 > 15.0]
    assert res.utilization > 0.75  # trailing chunks leave slots idle (paper 5.5.1)
