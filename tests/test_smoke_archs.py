"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and absence of NaNs; plus prefill->decode
consistency against the teacher-forced forward."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import api, io, stack


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_loss(arch, key):
    cfg = configs.get(arch, reduced=True)
    params = api.init_params(cfg, key)
    cell = io.smoke_cell("train", b=2, s=32)
    batch = io.make_batch(cfg, cell, key)
    loss_fn = stack.build_loss_fn(cfg)
    loss = jax.jit(loss_fn)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    # gradients exist and are finite
    grads = jax.jit(jax.grad(loss_fn))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in flat), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode_shapes(arch, key):
    cfg = configs.get(arch, reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32,
                              kv_dtype=jnp.float32)
    params = api.init_params(cfg, key)
    b, s = 2, 16
    cell = io.smoke_cell("prefill", b=b, s=s)
    batch = io.make_batch(cfg, cell, key)
    prefill = jax.jit(stack.build_prefill_fn(cfg, max_len=s + 4))
    decode = jax.jit(stack.build_decode_fn(cfg))
    cache, logits = prefill(params, batch)
    assert logits.shape == (b, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: NaN prefill logits"
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    cache, nxt, dlogits = decode(params, cache, tok, jnp.int32(s))
    assert nxt.shape == (b,)
    assert dlogits.shape == (b, cfg.vocab)
    assert jnp.all(jnp.isfinite(dlogits)), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen3-14b",
                                  "mamba2-780m", "jamba-v0.1-52b",
                                  "whisper-large-v3", "phi-3-vision-4.2b"])
def test_decode_matches_forward(arch, key):
    """Teacher-forced forward logits at position t must match
    prefill(t tokens) -> decode of token t."""
    cfg = configs.get(arch, reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32,
                              kv_dtype=jnp.float32)
    params = api.init_params(cfg, key)
    b, s = 2, 16
    cell = io.smoke_cell("train", b=b, s=s + 1)
    batch = io.make_batch(cfg, cell, key)
    # full teacher-forced forward over s+1 tokens
    h, _ = stack.forward(params, cfg, batch)
    full_logits = stack.unembed(params, cfg, h)      # [B, S+1, V]
    # prefill on the first s tokens, then decode token s
    pre_batch = dict(batch, tokens=batch["tokens"][:, :s])
    prefill = jax.jit(stack.build_prefill_fn(cfg, max_len=s + 1))
    decode = jax.jit(stack.build_decode_fn(cfg))
    cache, plogits = prefill(params, pre_batch)
    # prefill last-position logits == forward logits at position s-1
    assert jnp.allclose(plogits, full_logits[:, s - 1], atol=2e-4, rtol=2e-4), \
        f"{arch}: prefill/forward mismatch " \
        f"{jnp.max(jnp.abs(plogits - full_logits[:, s - 1]))}"
    tok = batch["tokens"][:, s:s + 1]
    _, _, dlogits = decode(params, cache, tok, jnp.int32(s))
    # SSD-hybrid archs recompute the scan state along a different reduction
    # order in the single-token decode path; on CPU the float32 drift
    # reaches ~8e-3 on these unnormalized logits depending on XLA's
    # per-process codegen partitioning (flaky at 2e-4)
    tol = 2e-2 if cfg.ssm is not None else 2e-4
    assert jnp.allclose(dlogits, full_logits[:, s], atol=tol, rtol=tol), \
        f"{arch}: decode/forward mismatch " \
        f"{jnp.max(jnp.abs(dlogits - full_logits[:, s]))}"
