"""Live daemon integration: multi-tenant jobs on a single-device shell.

(Multi-slot live execution is exercised by benchmarks/single_tenant.py in a
subprocess with xla_force_host_platform_device_count; unit tests must keep
the default 1-device view.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Daemon, Registry, Shell, default_registry, \
    uniform_shell
from repro.core.registry import ImplAlt, ModuleDescriptor
from repro.core import zoo


@pytest.fixture(scope="module")
def daemon():
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    reg.register_shell(spec)
    d = Daemon(Shell(spec), reg)
    yield d
    d.shutdown()


def _mandel_inputs(n=64, seed=0):
    rng = np.random.default_rng(seed)
    re = rng.uniform(-2, 1, (256, 256)).astype(np.float32)
    im = rng.uniform(-1.5, 1.5, (256, 256)).astype(np.float32)
    return re, im


def test_single_job_roundtrip(daemon):
    re, im = _mandel_inputs()
    h = daemon.submit("alice", "mandelbrot", [(re, im)])
    (out,) = h.future.result(timeout=120)
    prog = zoo.build_mandelbrot(daemon.shell.slots[0].mesh, 1)
    expected = jax.jit(prog.fn)(None, re, im)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expected))


def test_multi_tenant_concurrent_jobs(daemon):
    """Two tenants, different accelerators, data-parallel chunks."""
    re, im = _mandel_inputs(seed=1)
    img = np.random.default_rng(2).random((1024, 1024)).astype(np.float32)
    h1 = daemon.submit("alice", "mandelbrot", [(re, im)] * 3)
    h2 = daemon.submit("bob", "sobel", [(img,)] * 3)
    out1 = h1.future.result(timeout=300)
    out2 = h2.future.result(timeout=300)
    assert len(out1) == 3 and len(out2) == 3
    assert all(np.asarray(o).shape == (256, 256) for o in out1)
    assert all(np.asarray(o).shape == (1024, 1024) for o in out2)
    # cooperative time-multiplexing on one slot across tenants
    assert daemon.stats["chunks"] >= 7


def test_module_reuse_avoids_reload(daemon):
    re, im = _mandel_inputs(seed=3)
    before = daemon.stats["reconfigurations"]
    h = daemon.submit("alice", "mandelbrot", [(re, im)] * 4)
    h.future.result(timeout=300)
    # mandelbrot was already resident from earlier tests
    assert daemon.stats["reconfigurations"] <= before + 1
    assert daemon.stats["reuses"] > 0


def test_bus_adaptor_pads_and_casts(daemon):
    """Caller sends float64 and a smaller tile; adaptors fix it up."""
    re = np.zeros((200, 256), np.float64)
    im = np.zeros((200, 256), np.float64)
    h = daemon.submit("carol", "mandelbrot", [(re, im)])
    (out,) = h.future.result(timeout=120)
    assert np.asarray(out).shape == (256, 256)


def test_failing_chunk_leaves_no_orphaned_state(daemon):
    """Regression: a request resolved via set_exception used to leave its
    entry in `_results` (and its tenant queue head-of-line blocked) forever.
    A failing chunk must abort the request, drop all per-request state, and
    leave the scheduler consistent for subsequent work."""
    import time
    # oversize tiles violate the bus adaptor's signature check -> chunk error
    bad = (np.zeros((512, 512), np.float32),
           np.zeros((512, 512), np.float32))
    h = daemon.submit("erin", "mandelbrot", [bad, bad])
    with pytest.raises(AssertionError):
        h.future.result(timeout=120)
    deadline = time.time() + 30
    while time.time() < deadline:
        with daemon._lock:
            req = daemon.state.requests[h.rid]
            if req.finished and not daemon.state.alloc.busy:
                break
        time.sleep(0.05)
    with daemon._lock:
        assert h.rid not in daemon._results, "orphaned results buffer"
        assert h.rid not in daemon._handles, "orphaned handle"
        req = daemon.state.requests[h.rid]
        assert req.failed and req.finished
        assert not any(r.rid == h.rid for q in daemon.state.queues.values()
                       for r in q), "dead request still queued"
        assert not daemon.state.alloc.busy and not daemon.state.active
    # scheduler stays consistent: the same tenant can submit again
    re, im = _mandel_inputs(seed=9)
    h2 = daemon.submit("erin", "mandelbrot", [(re, im)])
    assert len(h2.future.result(timeout=120)) == 1


def test_registry_roundtrip(tmp_path):
    reg = default_registry()
    reg.save(tmp_path)
    reg2 = Registry.load(tmp_path)
    assert set(reg2.modules) == set(reg.modules)
    assert set(reg2.shells) == set(reg.shells)
    m = reg2.module("mandelbrot")
    assert m.footprints == [1, 2, 4]
    assert m.load_builder() is zoo.build_mandelbrot
