"""schedlint corpus: legitimate touch discipline — zero findings.

Covers the idioms the real scheduler uses: bump-inside-the-placement-
loop covering mutations before *and* after it on the same path, a
non-touching private helper covered by every public caller, branchy
code where every mutating path touches, and mutations of declared
untracked fields.
"""

SCHEDLINT_SIM = True
TRACKED_CLASS = "State"
TRACKED_FIELDS = ("queue", "active", "counter")
TRACKED_MUTATORS = ("append", "pop", "remove")
EXTERNAL_MUTATORS = ("submit", "complete")
UNTRACKED_FIELDS = {"_version": "the version counter itself",
                    "on_change": "wiring, not scheduling state",
                    "history": "reporting only, never read back"}


class State:
    def __init__(self):
        self.queue = []
        self.active = {}
        self.counter = 0
        self.history = []
        self._version = 0
        self.on_change = None

    def _touch(self):
        self._version += 1
        if self.on_change is not None:
            self.on_change()

    def _bump(self):
        self._version += 1

    def submit(self, item):
        self.queue.append(item)
        self.history.append(item)     # untracked: no bump required
        self._touch()

    def complete(self, key):
        if key not in self.active:
            return False              # no mutation on this path
        self.active.pop(key)
        self._retire(key)
        self._touch()
        return True

    def _retire(self, key):
        # helper mutates without touching: covered by its callers
        self.counter -= 1
        if key in self.queue:
            self.queue.remove(key)

    def schedule(self):
        placed = []
        while self.queue:
            item = self.queue.pop()
            self._bump()              # covers the whole iteration
            self.active[item] = True  # after the bump, same path
            placed.append(item)
        return placed
