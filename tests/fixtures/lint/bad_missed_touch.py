"""schedlint corpus: a tracked mutation with no version bump at all.

`submit` is an external mutator (fabric/executors call it between
scheduling passes): appending to the tracked queue without any
`_touch()` leaves the shell looking like a scheduling fixpoint.
Expected: flagged by the mutation checker (both the bump rule and the
stricter external-touch rule anchor on the same line).
"""

SCHEDLINT_SIM = True
TRACKED_CLASS = "State"
TRACKED_FIELDS = ("queue", "active")
TRACKED_MUTATORS = ("append", "pop", "remove")
EXTERNAL_MUTATORS = ("submit",)
UNTRACKED_FIELDS = {"_version": "the version counter itself"}


class State:
    def __init__(self):
        self.queue = []
        self.active = {}
        self._version = 0

    def _touch(self):
        self._version += 1

    def submit(self, item):
        self.queue.append(item)  # EXPECT: mutation
