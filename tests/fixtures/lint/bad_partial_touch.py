"""schedlint corpus: a touch that covers only one branch.

The mutation happens unconditionally but the `_touch()` sits under a
condition — there EXISTS a path (item <= 0) through the mutation that
reaches the exit untouched.  Expected: flagged by the mutation checker.
"""

SCHEDLINT_SIM = True
TRACKED_CLASS = "State"
TRACKED_FIELDS = ("queue",)
TRACKED_MUTATORS = ("append", "pop")
EXTERNAL_MUTATORS = ("submit",)
UNTRACKED_FIELDS = {"_version": "the version counter itself"}


class State:
    def __init__(self):
        self.queue = []
        self._version = 0

    def _touch(self):
        self._version += 1

    def submit(self, item):
        self.queue.append(item)  # EXPECT: mutation
        if item > 0:
            self._touch()
