"""schedlint corpus: deterministic use of sets and ordering — zero
findings.  Membership tests, `sorted()` iteration, `len`/`min`/`max`/
`any`/`all`, and dict iteration are all fine.
"""

SCHEDLINT_SIM = True


def place(pending, busy):
    free = {i for i in range(8) if i not in busy}
    if not free:
        return []
    out = []
    for i in sorted(free):            # sorted: deterministic
        if len(out) >= min(len(pending), max(1, len(free) // 2)):
            break
        out.append(i)
    return out


def ready(queues):
    # dict iteration is insertion-ordered: fine
    return [r for q in queues.values() for r in q if r > 0]


def any_free(busy, n):
    return any(i not in busy for i in range(n))
