"""schedlint corpus: an external mutator that bumps but never touches.

`_bump()` moves the version — enough for scheduling-internal mutations
— but never fires `on_change`, so a fabric's dirty set misses the
change entirely.  Methods declared in EXTERNAL_MUTATORS must `_touch`.
Expected: flagged by the mutation checker's external rule only (the
plain bump rule is satisfied).
"""

SCHEDLINT_SIM = True
TRACKED_CLASS = "State"
TRACKED_FIELDS = ("queue",)
TRACKED_MUTATORS = ("append", "pop")
EXTERNAL_MUTATORS = ("submit",)
UNTRACKED_FIELDS = {"_version": "the version counter itself",
                    "on_change": "wiring, not scheduling state"}


class State:
    def __init__(self):
        self.queue = []
        self._version = 0
        self.on_change = None

    def _touch(self):
        self._version += 1
        if self.on_change is not None:
            self.on_change()

    def _bump(self):
        self._version += 1

    def submit(self, item):
        self.queue.append(item)  # EXPECT: mutation
        self._bump()
