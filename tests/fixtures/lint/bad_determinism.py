"""schedlint corpus: every determinism rule violated once, in a module
declared to be on the simulator path.  Expected: one finding per
EXPECT line, none elsewhere.
"""

import os
import time  # EXPECT: determinism

SCHEDLINT_SIM = True


def stamp(events):
    return time.time()


def jitter(order):
    if os.environ.get("FAST"):  # EXPECT: determinism
        order.sort(key=lambda x: id(x))  # EXPECT: determinism
    return order


def drain(pending):
    ready = {p for p in pending if p > 0}
    total = sum(ready)  # EXPECT: determinism
    for p in ready:  # EXPECT: determinism
        total -= p
    return total
