"""schedlint corpus: a memo cache whose key misses a versioned read.

`Planner.load_ms` declares a cache keyed on the cost-model version
only, but the computation also reads `State.depth` — versioned shell
state.  A cached value survives the depth changing.  Expected: flagged
by the memo checker at the uncovered read.
"""

SCHEDLINT_SIM = True
SCHEDLINT_TYPES = {"Planner.cost": "CostModel", "Planner.shell": "State"}
SCHEDLINT_VERSIONED = {"CostModel.version": "cost",
                       "CostModel.per_chunk": "cost",
                       "State.depth": "state",
                       "State._version": "state"}
MEMO_CONTRACTS = (
    {"name": "load_ms", "func": "Planner.load_ms",
     "cache": "_load_cache", "key": ("cost",), "folded": {}},
)


class CostModel:
    def __init__(self):
        self.version = 0
        self.per_chunk = 1.0


class State:
    def __init__(self):
        self.depth = 0
        self._version = 0


class Planner:
    def __init__(self, shell, cost):
        self.shell = shell
        self.cost = cost
        self._load_cache = {}

    def load_ms(self):
        key = self.cost.version
        hit = self._load_cache.get(key)
        if hit is not None:
            return hit
        out = self.shell.depth * self.cost.per_chunk  # EXPECT: memo
        self._load_cache[key] = out
        return out
