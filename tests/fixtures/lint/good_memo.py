"""schedlint corpus: a memo whose key covers everything it reads —
zero findings.  Includes a folded token with a written justification
(the `recent` flag is resampled into `last_seen` before every query)
and safe reads of static configuration.
"""

SCHEDLINT_SIM = True
SCHEDLINT_TYPES = {"Planner.cost": "CostModel", "Planner.shell": "State"}
SCHEDLINT_VERSIONED = {"CostModel.version": "cost",
                       "CostModel.per_chunk": "cost",
                       "State.depth": "state",
                       "State._version": "state",
                       "State.last_seen": "reserve",
                       "Planner.scale": None}
MEMO_CONTRACTS = (
    {"name": "load_ms", "func": "Planner.load_ms",
     "cache": "_load_cache", "key": ("state", "cost"),
     "folded": {"reserve": "last_seen is refreshed from the event "
                           "loop before every query, so its changes "
                           "always arrive with a state bump"}},
)


class CostModel:
    def __init__(self):
        self.version = 0
        self.per_chunk = 1.0


class State:
    def __init__(self):
        self.depth = 0
        self.last_seen = 0.0
        self._version = 0


class Planner:
    def __init__(self, shell, cost):
        self.shell = shell
        self.cost = cost
        self.scale = 2.0              # static configuration
        self._load_cache = {}

    def load_ms(self):
        key = (self.shell._version, self.cost.version)
        hit = self._load_cache.get(key)
        if hit is not None:
            return hit
        out = (self.shell.depth * self.cost.per_chunk * self.scale
               + self.shell.last_seen)
        self._load_cache[key] = out
        return out
