"""SLO-aware admission control (core/slo.py).

Contract under test:
  - `QoSContract` validates its fields; an unknown degraded-module name
    raises the registry's rich KeyError at registration, through every
    front door (`Fabric.register_contract`, `Fabric.submit(contract=)`,
    `Daemon.register_contract`);
  - with any contract registered, `Fabric.submit` screens the offered
    job: ``ADMIT`` on a feasible fabric, ``DEGRADE`` transparently swaps
    the job to the contract's degraded module (offered name preserved in
    `FabricJob.degraded_from`), ``REJECT`` returns a never-scheduled job
    whose verdict names the predicted contract violation;
  - a stopped contract tenant's protected feasibility share decays with
    staleness, so background work rejected during its burst is admitted
    again after the stream goes quiet;
  - verdicts and per-tenant attainment thread through `SimResult.slo`,
    `request_meta`, and the live `Daemon` (`slo_stats`, futures failing
    with `AdmissionRejected`);
  - contracts are *fully optional*: with none registered the controller
    is never constructed, the admission knobs are inert, and every
    `SimResult` field is byte-identical to the pre-SLO contract
    (property here; the golden corpus pins the same thing against
    committed PR 6 fixtures);
  - the admission path joins the incremental-vs-full-reschedule
    equivalence discipline: the contracts golden trace produces
    identical dumps through both cores.
"""
from __future__ import annotations

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import ADMIT, AdmissionRejected, DEGRADE, Fabric, \
    ImplAlt, ModuleDescriptor, PolicyConfig, QoSContract, REJECT, \
    Registry, SimJob, simulate
from repro.core.slo import HISTORY_MAX

from tests.golden_traces import to_jsonable


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.4))))
    reg.register_module(ModuleDescriptor(
        name="lite", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 1.5),)))
    return reg


# -- contract validation ------------------------------------------------------

def test_contract_field_validation():
    with pytest.raises(ValueError, match="rate_per_s"):
        QoSContract("t", rate_per_s=0.0, deadline_ms=10.0)
    with pytest.raises(ValueError, match="deadline_ms"):
        QoSContract("t", rate_per_s=1.0, deadline_ms=-5.0)
    with pytest.raises(ValueError, match="percentile"):
        QoSContract("t", rate_per_s=1.0, deadline_ms=10.0,
                    percentile=1.0)
    c = QoSContract("t", rate_per_s=50.0, deadline_ms=100.0)
    assert c.ia_ms == pytest.approx(20.0)
    assert c.tail_factor == pytest.approx(2.9957, abs=1e-3)


def test_unknown_degraded_module_rich_keyerror():
    """The degraded-impl name is validated like `Registry.shell()` —
    the error names the unknown module and lists what is registered."""
    reg = _registry()
    fab = Fabric({"s0": 4}, reg, PolicyConfig())
    bad = QoSContract("t", rate_per_s=1.0, deadline_ms=100.0,
                      degraded="nope")
    with pytest.raises(KeyError) as ei:
        fab.register_contract(bad)
    msg = str(ei.value)
    assert "nope" in msg and "batch" in msg and "inter" in msg
    # same validation through the submit(contract=) sugar; the fabric
    # must be left contract-free (nothing was registered)
    fab2 = Fabric({"s0": 4}, reg, PolicyConfig())
    with pytest.raises(KeyError):
        fab2.submit("t", "inter", 1, contract=bad)
    assert fab2.slo is None or not fab2.slo.contracts.get("t")


# -- verdict semantics --------------------------------------------------------

def _contracted_fabric(deadline_ms=1e6, degraded=None, rate_per_s=20.0,
                       shells=None):
    reg = _registry()
    fab = Fabric(shells or {"s0": 4}, reg, PolicyConfig())
    fab.register_contract(QoSContract(
        "beta", rate_per_s=rate_per_s, deadline_ms=deadline_ms,
        degraded=degraded))
    return reg, fab


def test_admit_on_idle_fabric():
    reg, fab = _contracted_fabric()
    job = fab.submit("beta", "inter", 2, now=0.0)
    assert not job.rejected
    assert job.verdict is not None and job.verdict.action == ADMIT
    assert job.degraded_from is None
    att = fab.slo.attainment()["beta"]
    assert att["admitted"] == 1 and att["rejected"] == 0


def test_reject_names_the_predicted_violation():
    """Under a committed backlog the verdict carries which contract
    breaks and the predicted-vs-target numbers, and the job never
    enters the admission queue."""
    reg = _registry()
    fab = Fabric({"s0": 4}, reg, PolicyConfig())
    for i in range(8):                    # pre-contract: all admitted
        fab.submit("acme", "batch", 6, now=0.0)
    fab.schedule(now=0.0)                 # commit them to shell queues
    fab.register_contract(QoSContract(
        "beta", rate_per_s=20.0, deadline_ms=60.0), now=0.0)
    job = fab.submit("beta", "inter", 1, now=0.0)
    assert job.rejected and job.verdict.action == REJECT
    assert job.verdict.violated == "beta"
    assert "beta" in job.verdict.reason
    assert "60" in job.verdict.reason
    assert job.verdict.predicted_ms > 60.0
    assert job.subs == [] and job.gid not in [
        j.gid for j in fab._admission]
    att = fab.slo.attainment()
    assert att["beta"]["rejected"] == 1


def test_rejection_threads_through_simresult():
    """A rejected job appears in `request_meta` with its verdict but
    never in `request_latency`, and `SimResult.slo` carries the
    per-tenant counts."""
    reg, fab = _contracted_fabric(deadline_ms=60.0)
    # beta's first job anchors its protected stream; the heavy
    # background job would then add 240 slot-ms of predicted wait and
    # break the 60 ms contract, so it is shed
    res = simulate(reg, fab, [
        SimJob(0.0, "beta", "inter", 1, priority=2),
        SimJob(0.5, "acme", "batch", 6)])
    by_tenant = {m["tenant"]: (gid, m)
                 for gid, m in res.request_meta.items()}
    gid_acme, m_acme = by_tenant["acme"]
    gid_beta, m_beta = by_tenant["beta"]
    assert m_acme["verdict"] == REJECT and "beta" in m_acme["verdict_reason"]
    assert m_beta["verdict"] == ADMIT and "verdict_reason" not in m_beta
    assert gid_acme not in res.request_latency
    assert gid_beta in res.request_latency
    assert res.slo["acme"]["rejected"] == 1
    assert res.slo["beta"]["admitted"] == 1
    assert res.slo["beta"]["attainment"] == 1.0


def test_degrade_transparently_swaps_module():
    """An offered job that would break its own contract, whose degraded
    form fits, runs as the degraded module — the offered name survives
    in `degraded_from` and the attainment counters."""
    reg, fab = _contracted_fabric(deadline_ms=150.0, degraded="lite")
    # offered: 2x40 = 80 serial ms -> (wait + reconfig + 80) * ~3x tail
    # blows 150 ms; degraded: 2x1.5 = 3 serial ms fits easily
    job = fab.submit("beta", "batch", 2, now=0.0)
    assert not job.rejected
    assert job.verdict.action == DEGRADE
    assert job.module == "lite" and job.degraded_from == "batch"
    assert job.verdict.degraded_to == "lite"
    assert job.verdict.violated == "beta"
    assert fab.slo.attainment()["beta"]["degraded"] == 1
    # the simulator path records the verdict in request_meta and runs
    # the job to completion as the degraded module
    res2 = simulate(_registry(), _degrade_fabric(), [
        SimJob(0.0, "beta", "batch", 2, priority=2)])
    (gid,) = list(res2.request_meta)
    assert res2.request_meta[gid]["verdict"] == DEGRADE
    assert res2.request_meta[gid]["degraded_from"] == "batch"
    assert res2.slo["beta"]["degraded"] == 1
    assert res2.slo["beta"]["completed"] == 1
    # a degraded chunk takes lite's 1.5 ms, not batch's 40 ms
    assert res2.makespan < 20.0


def _degrade_fabric():
    reg = _registry()
    fab = Fabric({"s0": 4}, reg, PolicyConfig())
    fab.register_contract(QoSContract(
        "beta", rate_per_s=20.0, deadline_ms=150.0, degraded="lite"))
    return fab


def test_stopped_tenant_share_decays_and_readmits():
    """A contract tenant's declared-rate share protects capacity while
    it offers work; once it stops, staleness releases the share and a
    background submit rejected during the burst is admitted again."""
    reg, fab = _contracted_fabric(deadline_ms=1e6, rate_per_s=200.0)
    # the burst: establish beta's per-job cost (5 heavy jobs)
    for i in range(5):
        fab.submit("beta", "batch", 6, now=float(i))
    # during the burst the offered utilisation alone exceeds rho_max
    # (200/s x 240 slot-ms >> 4 slots), so background work is shed
    v_burst = fab.slo.decide("acme", "inter", 1, now=5.0)
    assert v_burst.action == REJECT
    # beta goes quiet: the protected share decays as
    # 1/(gap/STALE_FACTOR), so the same background submit is feasible
    v_later = fab.slo.decide("acme", "inter", 1, now=300000.0)
    assert v_later.action == ADMIT


def test_attainment_history_is_bounded():
    reg, fab = _contracted_fabric()
    ctl = fab.slo
    for i in range(HISTORY_MAX + 50):
        ctl.record_completion("beta", latency_ms=1.0, deadline_ms=None,
                              now=float(i))
    assert len(ctl.history["beta"]) == HISTORY_MAX
    att = ctl.attainment()["beta"]
    assert att["attainment"] == 1.0
    assert len(att["history"]) == HISTORY_MAX


def test_attainment_scores_against_job_deadline():
    """A finished job is scored against its own deadline when it has
    one, the contract deadline otherwise."""
    reg, fab = _contracted_fabric(deadline_ms=100.0)
    ctl = fab.slo
    ctl.record_completion("beta", 50.0, None, 1.0)     # hit (contract)
    ctl.record_completion("beta", 150.0, None, 2.0)    # miss (contract)
    ctl.record_completion("beta", 150.0, 200.0, 3.0)   # hit (own dl)
    a = ctl.attainment()["beta"]
    assert a["hits"] == 2 and a["misses"] == 1
    assert a["attainment"] == pytest.approx(2 / 3)
    # non-contract tenants are not scored
    ctl.record_completion("acme", 5.0, None, 4.0)
    assert "acme" not in ctl.history


# -- no-contract path is byte-identical ---------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000),
       st.integers(4, 14),
       st.booleans(),
       st.floats(0.05, 0.95),
       st.floats(0.2, 0.9))
def test_no_contract_path_ignores_admission_knobs(seed, n_jobs, preempt,
                                                  alpha, rho_max):
    """With no contract registered the controller never exists: the
    admission knobs are dead config, `SimResult.slo` is empty, and the
    full result dump is byte-identical across any knob values."""
    rng = np.random.default_rng(seed)
    jobs = []
    t = 0.0
    for _ in range(n_jobs):
        t += float(rng.exponential(6.0)) + 1e-3
        if rng.random() < 0.5:
            jobs.append(SimJob(t, "acme", "batch", int(rng.integers(2, 6))))
        else:
            jobs.append(SimJob(t, "beta", "inter", int(rng.integers(1, 4)),
                               priority=2, deadline_ms=30.0))
    dumps = []
    for a, r in ((0.3, 0.95), (alpha, rho_max)):
        pol = PolicyConfig(preemptive=preempt, transfer_ms=1.0,
                           reserve_mode="adaptive", reserve_slots_max=1,
                           admission_alpha=a, admission_rho_max=r)
        fab = Fabric({"s0": (4, 1.0), "s1": (2, 1.5)}, _registry(), pol)
        res = simulate(fab.registry, fab, jobs)
        assert fab.slo is None
        assert res.slo == {}
        d = to_jsonable(res)
        assert "slo" not in d             # pre-SLO serialised shape
        dumps.append(d)
    assert dumps[0] == dumps[1]


# -- equivalence: admission + incremental core --------------------------------

def test_contracts_trace_incremental_equals_full_reschedule():
    """The contracts golden trace through the incremental core and the
    reference full-reschedule core — identical dumps, so the admission
    path inherits PR 6's equivalence discipline."""
    from tests.golden_traces import TRACES
    dumps = []
    for full in (False, True):
        reg, fab, jobs = TRACES["contracts_full"]()
        fab.full_reschedule = full
        dumps.append(to_jsonable(simulate(reg, fab, jobs)))
    assert dumps[0] == dumps[1]


# -- live daemon --------------------------------------------------------------

def test_daemon_contract_reject_and_attainment():
    """Live front door: a generous contract admits and scores, a
    hopeless one fails the future with `AdmissionRejected` carrying the
    structured verdict, and `slo_stats` reports both."""
    from repro.core import Daemon, Shell, default_registry, uniform_shell
    spec = uniform_shell("slo1_s1", (1, 1), 1)
    reg = default_registry()
    reg.register_shell(spec)
    d = Daemon(Shell(spec), reg)
    try:
        with pytest.raises(KeyError, match="registered"):
            d.register_contract(QoSContract(
                "live", rate_per_s=1.0, deadline_ms=100.0,
                degraded="no-such-module"))
        d.register_contract(QoSContract(
            "live", rate_per_s=1.0, deadline_ms=1e9))
        rng = np.random.default_rng(0)
        img = rng.random((1024, 1024)).astype(np.float32)
        h = d.submit("live", "sobel", [(img,)])
        (out,) = h.future.result(timeout=300)
        assert np.asarray(out).shape == (1024, 1024)
        # a deadline below the reconfiguration penalty alone can never
        # be met: predicted violation, future fails, nothing runs
        d.register_contract(QoSContract(
            "doomed", rate_per_s=1.0, deadline_ms=1e-3))
        h2 = d.submit("doomed", "sobel", [(img,)])
        with pytest.raises(AdmissionRejected) as ei:
            h2.future.result(timeout=60)
        assert ei.value.verdict.action == REJECT
        assert ei.value.verdict.violated == "doomed"
        stats = d.slo_stats
        assert stats["live"]["admitted"] >= 1
        assert stats["live"]["completed"] >= 1
        assert stats["live"]["attainment"] is not None
        assert stats["doomed"]["rejected"] == 1
        # the daemon stays serviceable after a rejection
        h3 = d.submit("live", "sobel", [(img,)])
        h3.future.result(timeout=300)
    finally:
        d.shutdown()
