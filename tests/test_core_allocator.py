"""Property tests for the buddy slot allocator."""
from __future__ import annotations

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.allocator import BuddyAllocator, Range


@given(st.sampled_from([1, 2, 3, 4, 5, 6, 8]),
       st.lists(st.tuples(st.sampled_from(["alloc1", "alloc2", "alloc4",
                                           "free"]),
                          st.integers(0, 100)), max_size=60))
@settings(max_examples=200, deadline=None)
def test_allocator_invariants(n_slots, ops):
    a = BuddyAllocator(n_slots)
    live: list[Range] = []
    for op, arg in ops:
        if op == "free" and live:
            r = live.pop(arg % len(live))
            a.free(r)
        elif op.startswith("alloc"):
            size = int(op[5:])
            r = a.alloc(size)
            if r is not None:
                # aligned, in range, power-of-two
                assert r.start % r.size == 0
                assert r.start + r.size <= n_slots
                live.append(r)
    # no double allocation: busy == union of live ranges, sizes consistent
    claimed = [i for r in live for i in r.slots]
    assert sorted(claimed) == sorted(a.busy)
    assert len(set(claimed)) == len(claimed)


def test_merge_and_split_cycle():
    a = BuddyAllocator(4)
    r1 = a.alloc(1)
    r4 = a.alloc(4)
    assert r4 is None, "cannot merge past a busy buddy"
    r2 = a.alloc(2)
    assert r2 is not None and r2.start == 2, "aligned run chosen"
    a.free(r1)
    assert a.alloc(2).start == 0
    assert a.largest_free() == 0


def test_largest_free_tracks_merges():
    a = BuddyAllocator(8)
    assert a.largest_free() == 8
    r = a.alloc(1)
    assert a.largest_free() == 4
    a.free(r)
    assert a.largest_free() == 8
