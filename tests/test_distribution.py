"""Distribution correctness: sharded execution must match single-device
numerics.  Runs in a subprocess with 8 fake host devices so the main test
process keeps its 1-device view."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro import configs
from repro.launch import steps as steps_mod
from repro.models import api, io, stack
from repro.optim import adamw
from repro.sharding import partition

failures = []

def check(name, a, b, tol=2e-4):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9)
    if not np.isfinite(err) or err > tol:
        failures.append(f"{name}: rel err {err}")

for arch in ["llama3.2-3b", "qwen3-moe-30b-a3b", "mamba2-780m",
             "jamba-v0.1-52b", "whisper-large-v3", "phi-3-vision-4.2b"]:
    cfg = configs.get(arch, reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32,
                              kv_dtype=jnp.float32)
    if cfg.moe is not None:
        # capacity large enough that no tokens drop: dense vs EP dispatch
        # then agree exactly (capacity-binding drop order is impl-defined)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, impl="ep", capacity_factor=8.0))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    cell = io.smoke_cell("train", b=4, s=32)
    batch = io.make_batch(cfg, cell, jax.random.PRNGKey(1))

    # single-device reference (dense MoE oracle)
    ref_cfg = (dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, impl="dense")) if cfg.moe is not None else cfg)
    ref_loss = stack.build_loss_fn(ref_cfg)(params, batch)

    # sharded: 2x4 mesh, train rules
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = partition.make_rules("train")
    loss_fn = stack.build_loss_fn(cfg, mesh, batch_axes=rules.batch_axes)
    state_sh = partition.tree_shardings(api.param_specs(cfg), mesh, rules)
    batch_sh = partition.tree_shardings(
        io.input_axis_specs(cfg, cell)["batch"], mesh, rules)
    with jax.set_mesh(mesh):
        def wrapped(p, b):
            with partition.use_rules(rules):
                return loss_fn(p, b)
        sh_loss = jax.jit(wrapped, in_shardings=(state_sh, batch_sh))(
            jax.device_put(params, state_sh),
            jax.device_put(batch, batch_sh))
    check(f"{arch}/train_loss", sh_loss, ref_loss,
          tol=5e-3 if cfg.moe is not None else 2e-4)

    # decode path with sequence-sharded cache vs local cache
    serve_rules = partition.make_rules("serve")
    b_, s_ = 4, 16
    pcell = io.smoke_cell("prefill", b=b_, s=s_)
    pbatch = io.make_batch(cfg, pcell, jax.random.PRNGKey(2))
    prefill_ref = jax.jit(stack.build_prefill_fn(ref_cfg, max_len=s_ + 2))
    decode_ref = jax.jit(stack.build_decode_fn(ref_cfg))
    cache_r, logits_r = prefill_ref(params, pbatch)
    tok = jnp.argmax(logits_r, -1)[:, None].astype(jnp.int32)
    _, _, dlogits_r = decode_ref(params, cache_r, tok, jnp.int32(s_))

    with jax.set_mesh(mesh):
        def pre(p, b):
            with partition.use_rules(serve_rules):
                return stack.build_prefill_fn(
                    cfg, max_len=s_ + 2, mesh=mesh,
                    batch_axes=serve_rules.batch_axes)(p, b)
        def dec(p, c, t, pos):
            with partition.use_rules(serve_rules):
                return stack.build_decode_fn(
                    cfg, mesh=mesh,
                    batch_axes=serve_rules.batch_axes)(p, c, t, pos)
        params_sh = jax.device_put(params, partition.tree_shardings(
            api.param_specs(cfg), mesh, serve_rules))
        cache_s, logits_s = jax.jit(pre)(params_sh, pbatch)
        check(f"{arch}/prefill_logits", logits_s, logits_r, tol=1e-3)
        _, _, dlogits_s = jax.jit(dec)(params_sh, cache_s, tok,
                                       jnp.int32(s_))
        check(f"{arch}/decode_logits", dlogits_s, dlogits_r, tol=1e-3)

if failures:
    print("FAILURES:", failures)
    raise SystemExit(1)
print("DISTRIBUTION_OK")
"""


@pytest.mark.slow
def test_sharded_matches_single_device():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _CODE], env=env,
                         capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-3000:]
    assert "DISTRIBUTION_OK" in out.stdout
