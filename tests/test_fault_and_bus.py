"""Watchdog/straggler handling and bus-adaptor property tests."""
from __future__ import annotations

import time

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.ckpt.fault import FaultInjector, InjectedFault, StepTimeout, \
    Watchdog, run_with_restarts
from repro.core import bus


def test_watchdog_fires_on_stall():
    fired = []
    wd = Watchdog(0.2, on_timeout=lambda: fired.append(1)).start()
    time.sleep(0.5)
    wd.stop()
    assert wd.fired and fired


def test_watchdog_heartbeat_keeps_alive():
    wd = Watchdog(0.4, on_timeout=lambda: None).start()
    for _ in range(5):
        time.sleep(0.1)
        wd.beat()
    assert not wd.fired
    wd.stop()


def test_fault_injector_fires_once():
    inj = FaultInjector(fail_at_step=3)
    inj.check(2)
    with pytest.raises(InjectedFault):
        inj.check(3)
    inj.check(3)   # second pass does not re-fire (restart proceeds)


def test_run_with_restarts_straggler_path():
    calls = []

    def run_fn(start):
        calls.append(start)
        if len(calls) < 3:
            raise StepTimeout("straggler")
        return 10

    final, restarts = run_with_restarts(run_fn, log=lambda *a: None)
    assert final == 10 and restarts == 2


def test_run_with_restarts_gives_up():
    def run_fn(start):
        raise StepTimeout("dead")
    with pytest.raises(StepTimeout):
        run_with_restarts(run_fn, max_restarts=2, log=lambda *a: None)


@given(st.integers(1, 64), st.integers(1, 64),
       st.sampled_from(["float32", "float64", "int32"]))
@settings(max_examples=25, deadline=None)
def test_adaptor_pad_cast_roundtrip(rows, cols, dtype):
    """Adapted inputs always match the target signature; original content
    is preserved in the top-left corner."""
    want = (jax.ShapeDtypeStruct((64, 64), jnp.float32),)
    src = np.arange(rows * cols, dtype=dtype).reshape(rows, cols)
    (out,), rep = bus.adapt_inputs((src,), want)
    assert out.shape == (64, 64) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out)[:rows, :cols],
                               src.astype(np.float32))
    if rows == 64 and cols == 64 and dtype == "float32":
        assert rep.identity
    else:
        assert not rep.identity


def test_adaptor_rejects_oversize():
    want = (jax.ShapeDtypeStruct((8, 8), jnp.float32),)
    with pytest.raises(AssertionError):
        bus.adapt_inputs((np.zeros((9, 8), np.float32),), want)
