"""Predictive reservation (core/arrivals.py) + reservation bugfix sweep.

Contract under test:
  - `ArrivalEstimator` EWMAs inter-arrival/service/footprint per
    priority class, degrades a class's rate once the gap since its last
    arrival goes stale, and turns the rates into a Little's-law slot
    demand over the blocking + reconfiguration + service horizon;
  - `PolicyConfig.reserve_mode = "adaptive"` sizes each shell's
    effective reservation from that demand every scheduling pass
    (raising immediately, shrinking with hysteresis), records the trace
    in `reserve_history`, and with *zero* interactive arrivals is
    byte-identical to `reserve_slots=0`;
  - every chunk still completes exactly once under adaptive reservation
    + preemption + checkpointed migration at mixed shell speeds;
  - reserved slots are not steal targets: the thief's steal sizing
    counts only windows outside the reservation, and ECT dispatch
    spreads a batch job over the slots its class may actually use;
  - bugfix regressions: the unplaceable-forever waiver *shrinks* the
    reservation to the largest feasible value instead of dropping it to
    zero; `_n_free_ranges` counts a maximal non-overlapping packing
    (never overlapping windows); a tenant starved for a full
    starvation bound pierces the reserve after aging, while a
    backlogged-but-served tenant never does.
"""
from __future__ import annotations

import dataclasses
from collections import Counter

import hypothesis.strategies as st
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import ArrivalEstimator, Daemon, Fabric, ImplAlt, \
    ModuleDescriptor, PolicyConfig, Registry, Shell, SimJob, \
    default_registry, simulate, uniform_shell
from repro.core.allocator import BuddyAllocator
from repro.core.arrivals import STALE_FACTOR
from repro.core.scheduler import SchedulerState


def _registry() -> Registry:
    reg = Registry()
    reg.register_module(ModuleDescriptor(
        name="batch", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 40.0), ImplAlt("x2", 2, 22.0))))
    reg.register_module(ModuleDescriptor(
        name="inter", entrypoint="x:y",
        impls=(ImplAlt("x1", 1, 4.0), ImplAlt("x2", 2, 2.4))))
    return reg


def _wide_registry() -> Registry:
    reg = _registry()
    reg.register_module(ModuleDescriptor(
        name="wide", entrypoint="x:y",
        impls=(ImplAlt("x2", 2, 10.0),)))
    return reg


# -- estimator unit behavior --------------------------------------------------

def test_estimator_ewma_staleness_and_classes():
    est = ArrivalEstimator(alpha=0.5)
    est.observe(3, 0.0, service_ms=4.0)
    assert est.interarrival_ms(3) is None           # one arrival: no rate
    assert est.rate_per_ms(3, 100.0) == 0.0
    est.observe(3, 10.0, service_ms=4.0)
    assert est.interarrival_ms(3) == 10.0
    est.observe(3, 30.0, service_ms=4.0)
    assert est.interarrival_ms(3) == 15.0           # 0.5*20 + 0.5*10
    assert est.rate_per_ms(3, 30.0) == pytest.approx(1 / 15)
    # staleness: the rate only degrades once the gap since the last
    # arrival exceeds STALE_FACTOR expected inter-arrivals
    assert est.rate_per_ms(3, 30.0 + STALE_FACTOR * 15.0) \
        == pytest.approx(1 / 15)
    assert est.rate_per_ms(3, 30.0 + STALE_FACTOR * 30.0) \
        == pytest.approx(1 / 30)
    # classes are independent
    est.observe(0, 0.0, service_ms=40.0)
    assert est.interarrival_ms(0) is None
    assert est.rate_per_ms(0, 30.0) == 0.0


def test_estimator_demand_slots_formula():
    est = ArrivalEstimator(alpha=1.0)
    est.observe(0, 0.0, service_ms=40.0)            # batch: blocking term
    est.observe(3, 0.0, service_ms=4.0, footprint=2)
    est.observe(3, 10.0, service_ms=4.0, footprint=2)
    assert est.blocking_ms(3) == 40.0
    # rate 1/10 x ((blocking 40 + service 4) / speed + overhead) x fp 2
    assert est.demand_slots(3, 10.0, overhead_ms=5.0) \
        == pytest.approx((1 / 10) * (44.0 + 5.0) * 2)
    assert est.demand_slots(3, 10.0, overhead_ms=5.0, speed=2.0) \
        == pytest.approx((1 / 10) * (22.0 + 5.0) * 2)
    # no class at or above min_priority -> zero demand
    assert est.demand_slots(5, 10.0, overhead_ms=5.0) == 0.0
    with pytest.raises(ValueError):
        ArrivalEstimator(alpha=0.0)


def test_estimator_single_arrival_contributes_no_demand():
    """One arrival fixes service/footprint EWMAs but no inter-arrival,
    so the class has rate 0 and adds nothing to demand — a lone probe
    job must not inflate the reservation."""
    est = ArrivalEstimator(alpha=0.5)
    est.observe(3, 5.0, service_ms=4.0, footprint=2)
    assert est.interarrival_ms(3) is None
    assert est.rate_per_ms(3, 5.0) == 0.0
    assert est.demand_slots(3, 5.0, overhead_ms=5.0) == 0.0
    # the lone batch observation still supplies the blocking term once
    # a *rated* interactive class exists
    est.observe(0, 0.0, service_ms=40.0)
    assert est.blocking_ms(3) == 40.0
    assert est.demand_slots(3, 5.0) == 0.0          # still no rate


def test_estimator_stopped_stream_releases_demand():
    """A stream that stops arriving decays to rate 0, and with it the
    demand share it was holding: the adaptive reservation frees the
    capacity instead of predicting the burst forever."""
    est = ArrivalEstimator(alpha=0.5)
    for t in (0.0, 10.0, 20.0, 30.0):
        est.observe(3, t, service_ms=4.0)
    active = est.demand_slots(3, 30.0)
    assert active == pytest.approx((1 / 10) * 4.0)
    # inside the staleness grace window the share is untouched...
    assert est.demand_slots(3, 30.0 + STALE_FACTOR * 10.0) \
        == pytest.approx(active)
    # ...then decays hyperbolically with the gap: 1% of the share left
    # after 100 grace windows, vanishing in the limit
    far = 30.0 + 100.0 * STALE_FACTOR * 10.0
    assert est.demand_slots(3, far) == pytest.approx(active / 100)
    assert est.demand_slots(3, 1e12) < 1e-6


def test_estimator_memo_invalidated_by_new_class():
    """demand_slots memoizes per (now, observation version): a new
    priority class appearing between two same-instant queries must be
    visible to the second one, not masked by the memo."""
    est = ArrivalEstimator(alpha=1.0)
    est.observe(3, 0.0, service_ms=4.0)
    est.observe(3, 10.0, service_ms=4.0)
    base = est.demand_slots(3, 10.0)
    assert base == pytest.approx((1 / 10) * 4.0)
    assert est.demand_slots(3, 10.0) is est.demand_slots(3, 10.0) \
        or est.demand_slots(3, 10.0) == base        # memo hit, same value
    # a brand-new higher class appears "mid-instant" (e.g. admitted by
    # another shell's pass at the same virtual time)
    est.observe(5, 5.0, service_ms=8.0)
    est.observe(5, 10.0, service_ms=8.0)
    bumped = est.demand_slots(3, 10.0)
    assert bumped == pytest.approx(base + (1 / 5) * 8.0)
    # and the per-key cache still serves distinct (overhead, speed)
    # keys correctly after the invalidation
    assert est.demand_slots(3, 10.0, overhead_ms=2.0) \
        == pytest.approx((1 / 10) * 6.0 + (1 / 5) * 10.0)


def test_reserve_mode_typo_rejected():
    """A misspelled reserve_mode must fail loudly, not silently fall
    back to the static path with the operator believing adaptive
    protection is on."""
    with pytest.raises(ValueError, match="reserve_mode"):
        SchedulerState(4, _registry(),
                       PolicyConfig(reserve_mode="Adaptive"))
    with pytest.raises(ValueError, match="reserve_mode"):
        Fabric({"a": 2}, _registry(),
               PolicyConfig(reserve_mode="adaptative"))


def test_effective_reserve_rounds_with_hysteresis():
    st_ = SchedulerState(4, _registry(),
                         PolicyConfig(reserve_mode="adaptive",
                                      reserve_slots_max=4))
    est = st_.arrivals                              # bare state owns one
    est.observe(0, 0.0, service_ms=40.0)
    est.observe(1, 0.0, service_ms=5.0)
    est.observe(1, 50.0, service_ms=5.0)
    # demand = (1/50) x (40 + 5 + reconfig 5) = 1.0 -> reserve 1
    st_.schedule(now=50.0)
    assert st_._reserve_last == 1
    assert st_.reserve_history == [(50.0, 1)]
    # demand decayed into the hysteresis band (0.25..0.5): hold at 1
    hold_at = 50.0 + STALE_FACTOR * (50.0 / 0.4)
    assert st_.effective_reserve(hold_at) == 1
    # decayed below the band: release
    drop_at = 50.0 + STALE_FACTOR * (50.0 / 0.2)
    assert st_.effective_reserve(drop_at) == 0


# -- adaptive sizing end to end -----------------------------------------------

def test_adaptive_reservation_tracks_arrival_rate():
    """A steady 10 ms interactive stream over saturating batch raises
    the reservation, protects the interactive p95, and the reservation
    decays back to zero after the stream stops (reserve_history shows
    both transitions)."""
    reg = _registry()
    # batch outlives the interactive stream by well over the staleness
    # horizon, so the post-burst decay has events to be observed at
    jobs = [SimJob(0.0, "b", "batch", 100),
            SimJob(0.0, "b2", "batch", 100)]
    jobs += [SimJob(float(t), "live", "inter", 1, priority=3)
             for t in range(5, 400, 10)]
    res = simulate(reg, 4, jobs,
                   PolicyConfig(preemptive=False, reserve_mode="adaptive",
                                reserve_slots_max=2,
                                starvation_bound_ms=1e9))
    hist = res.reserve_history["shell0"]
    assert hist, "no sizing decisions recorded"
    assert max(n for _, n in hist) >= 1             # raised while hot
    assert hist[-1][1] == 0                         # decayed after stop

    def settled_p95(r):
        # the first 100 ms are the cold start: the estimator needs two
        # arrivals and the reserved slot must drain its batch chunk
        from repro.core.simulator import p95
        return p95([lat for rid, lat in r.request_latency.items()
                    if r.request_meta[rid]["priority"] == 3
                    and r.request_meta[rid]["t_submit"] >= 100.0])

    assert settled_p95(res) <= 15.0                 # protected
    # static zero-reservation leaves the stream behind 40 ms chunks
    base = simulate(reg, 4, jobs,
                    PolicyConfig(preemptive=False,
                                 starvation_bound_ms=1e9))
    assert settled_p95(base) > 25.0


zero_inter_jobs = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "u2"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, "a", "b"])),
    min_size=1, max_size=15)


@given(zero_inter_jobs,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]),
       st.booleans())
@settings(max_examples=50, deadline=None)
def test_adaptive_zero_interactive_matches_reserve0(raw, sizes, preempt):
    """With no arrival at or above reserve_priority the adaptive
    reservation stays 0 and the SimResult is byte-identical to
    `reserve_slots=0` — every field, reserve_history included."""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    shells = {"a": sizes[0], "b": sizes[1]}
    base = simulate(_registry(), shells, jobs,
                    PolicyConfig(preemptive=preempt, steal=True,
                                 reserve_slots=0, reserve_priority=5))
    adapt = simulate(_registry(), shells, jobs,
                     PolicyConfig(preemptive=preempt, steal=True,
                                  reserve_mode="adaptive",
                                  reserve_slots_max=2,
                                  reserve_priority=5))
    assert dataclasses.asdict(base) == dataclasses.asdict(adapt)
    assert all(not h for h in adapt.reserve_history.values())


mixed_jobs = st.lists(
    st.tuples(st.floats(0, 200),
              st.sampled_from(["u0", "u1", "hi"]),
              st.sampled_from(["batch", "inter"]),
              st.integers(1, 6),
              st.integers(0, 3),
              st.sampled_from([None, "a", "b"])),
    min_size=1, max_size=15)


@given(mixed_jobs,
       st.sampled_from([(1, 1), (2, 1), (2, 2), (4, 2)]),
       st.sampled_from([(1.0, 1.0), (0.5, 2.0), (1.0, 0.25)]),
       st.sampled_from([0.0, 1.0]))
@settings(max_examples=50, deadline=None)
def test_exactly_once_under_adaptive_reserve_and_migration(
        raw, sizes, speeds, transfer):
    """Adaptive reservation + preemption + checkpointed migration on
    mixed-speed shells: every chunk completes exactly once, capacity
    holds over completed and evicted spans, and no record leaks."""
    jobs = [SimJob(t, u, m, c, priority=p, affinity=aff)
            for t, u, m, c, p, aff in raw]
    fab = Fabric({"a": (sizes[0], speeds[0]), "b": (sizes[1], speeds[1])},
                 _registry(),
                 PolicyConfig(preemptive=True, steal=True, ckpt=True,
                              transfer_ms=transfer,
                              reserve_mode="adaptive",
                              reserve_slots_max=2))
    res = simulate(_registry(), fab, jobs)
    done = Counter(rid for *_, rid in res.timeline)
    for rid, meta in res.request_meta.items():
        assert done[rid] == meta["n_chunks"], \
            f"rid {rid}: {done[rid]} completions != {meta['n_chunks']}"
    spans = list(res.timeline) + list(res.preempted_spans)
    events = []
    for t0, t1, (s, size), _ in spans:
        events += [(t0, size), (t1, -size)]
    busy = 0
    for _, d in sorted(events, key=lambda e: (e[0], e[1])):
        busy += d
        assert busy <= sum(sizes)
    assert abs(res.discarded_ms + res.reclaimed_ms
               - res.wasted_time) < 1e-6
    assert len(fab.ckpt) == 0, "leaked checkpoint records"


# -- fabric consistency: dispatch + stealing ----------------------------------

def test_reserved_slots_are_not_steal_targets():
    """A thief whose only free window is reserved must not pull batch
    chunks it cannot place: the steal is sized to the windows outside
    the reservation and skipped when none remain."""
    fab = Fabric({"a": 2, "b": 2}, _registry(),
                 PolicyConfig(reserve_slots=1, steal=True))
    fab.submit("t", "batch", 8, now=0.0)
    fab.schedule(now=0.0)
    # each shell runs exactly one batch chunk in its non-reserved slot
    assert set(fab.states["a"].alloc.busy) == {0}
    assert set(fab.states["b"].alloc.busy) == {0}
    # the thief stole only what it could place outside the reserve —
    # and nothing more once only the reserved slot was left
    assert fab.stats["stolen_chunks"] == 1
    assert fab.states["b"].pending_chunks() == 0


def test_ect_dispatch_excludes_reserved_slots():
    """ECT spreads a batch job over the slots its class may use; an
    interactive job still sees the whole shell."""
    fab = Fabric({"a": 2}, _registry(), PolicyConfig(reserve_slots=1))
    lo = fab.submit("t", "batch", 2, now=0.0)
    hi = fab.submit("t2", "inter", 2, now=0.0, priority=3)
    # batch: 2 chunks x 40 ms over (2 - 1) usable slots
    assert fab._ect("a", lo) == pytest.approx(80.0)
    # interactive: 2 chunks x 4 ms over both slots
    assert fab._ect("a", hi) == pytest.approx(4.0)


# -- bugfix: waiver shrinks instead of dropping to zero -----------------------

def test_reserve_shrinks_to_largest_feasible_value():
    """A big-footprint module must not silently disable interactive
    protection: the reservation shrinks to `n - min_footprint` instead
    of dropping to 0."""
    st_ = SchedulerState(4, _wide_registry(),
                         PolicyConfig(reserve_slots=3))
    assert st_.reserve_for_class(0, "inter") == 3   # fp 1 fits under 3
    assert st_.reserve_for_class(0, "wide") == 2    # shrunk, not waived
    assert st_.reserve_for_class(3, "wide") == 0    # interactive class
    # end to end: a second wide batch request cannot take slots 2-3
    st_.submit("t1", "wide", 1, now=0.0)
    st_.submit("t2", "wide", 1, now=0.0)
    issued = st_.schedule(now=0.0)
    assert len(issued) == 1 and issued[0].rng.start == 0
    # the all-or-nothing waiver would have placed the second request
    # into the reserved window (slots 2-3) at the same instant


def test_reserve_shrink_keeps_module_placeable():
    """The shrunk reservation still leaves a feasible window — no
    wedged jobs (the original waiver's guarantee is preserved)."""
    res = simulate(_wide_registry(), 2, [SimJob(0.0, "b", "wide", 1)],
                   PolicyConfig(reserve_slots=1))
    assert res.makespan == 15.0                     # reconfig 5 + 10


# -- bugfix: _n_free_ranges counts a non-overlapping packing ------------------

def test_n_free_ranges_value_anchors_on_buddy_alignment():
    st_ = SchedulerState(4, _registry())
    assert st_._n_free_ranges(1) == 4
    assert st_._n_free_ranges(2) == 2
    assert st_._n_free_ranges(4) == 1
    st_.alloc.busy.add(1)
    assert st_._n_free_ranges(2) == 1               # only (2, 3)
    assert st_._n_free_ranges(2, within=3) == 0
    assert st_._n_free_ranges(1, within=3) == 2     # slots 0, 2


def test_n_free_ranges_never_counts_overlapping_windows():
    """With a finer-than-buddy alignment, overlapping free starts must
    collapse to a maximal disjoint packing — counting each start would
    overstate the concurrency `_choose`'s rate model plans for."""
    class FineAllocator(BuddyAllocator):
        def aligned_starts(self, size):             # alignment 1
            return range(0, self.n - size + 1)

    st_ = SchedulerState(3, _registry())
    st_.alloc = FineAllocator(3)
    # free slots 0-2, footprint 2: starts 0 and 1 overlap -> one window
    assert st_._n_free_ranges(2) == 1
    st5 = SchedulerState(5, _registry())
    st5.alloc = FineAllocator(5)
    st5.alloc.busy.add(2)
    # free runs [0,1] and [3,4]: exactly one window each
    assert st5._n_free_ranges(2) == 2


# -- bugfix: starvation waiver vs backlogged tenants --------------------------

def test_starved_tenant_pierces_reserve_after_aging():
    """Interactive traffic saturates the only non-reserved slot: the
    batch tenant gets no service at all, ages to the reserve priority,
    and after a full starvation bound may place into the reserve —
    bounded delay instead of starving forever outside an idle slot."""
    st_ = SchedulerState(2, _registry(),
                         PolicyConfig(reserve_slots=1,
                                      starvation_bound_ms=100.0))
    batch = st_.submit("b", "batch", 1, now=0.0)
    hi = st_.submit("live", "inter", 1, now=0.0, priority=3)
    (a,) = st_.schedule(now=0.0)                    # hi takes slot 0
    assert a.rid == hi.rid and a.rng.start == 0
    assert batch.pending == 1
    for t in [float(x) for x in range(4, 97, 4)]:   # keep slot 0 hot
        assert st_.complete(a, now=t)
        hi = st_.submit("live", "inter", 1, now=t, priority=3)
        issued = st_.schedule(now=t)
        assert [x.rid for x in issued] == [hi.rid]
        assert issued[0].rng.start == 0
        assert batch.pending == 1, "pierced the reserve before aging"
        a = issued[0]
    st_.complete(a, now=104.0)
    hi = st_.submit("live", "inter", 1, now=104.0, priority=3)
    issued = st_.schedule(now=104.0)                # aged + starved now
    by_rid = {x.rid: x for x in issued}
    assert batch.pending == 0
    assert by_rid[batch.rid].rng.start == 1         # into the reserve


def test_backlogged_tenant_does_not_pierce_reserve():
    """A tenant whose earlier requests are served continuously is not
    starved: its aged queue entries stay out of the reserved slot even
    when they out-age the reserve priority."""
    jobs = [SimJob(0.0, "b", "batch", 6), SimJob(0.0, "b", "batch", 6)]
    res = simulate(_registry(), 2, jobs,
                   PolicyConfig(reserve_slots=1,
                                starvation_bound_ms=100.0))
    # 12 chunks x 40 ms serially: plenty of aging past the bound, yet
    # every placement stays in slot 0 — the reserve never hosts batch
    assert res.makespan > 400.0
    for t0, t1, (s, size), rid in res.timeline:
        assert s == 0 and size == 1, \
            "backlogged batch pierced the reserved slot"


def test_tenant_service_signal_is_fabric_wide():
    """The starvation waiver sees service on *any* shell: a stolen
    sub-request of a tenant served elsewhere is backlogged, not
    starved, and must not pierce the thief's reserve."""
    fab = Fabric({"a": 1, "b": 2}, _registry(),
                 PolicyConfig(reserve_slots=1, steal=False,
                              starvation_bound_ms=50.0))
    sa, sb = fab.states["a"], fab.states["b"]
    sa.submit("t", "batch", 2, now=0.0)
    sa.schedule(now=100.0)          # service on a, recorded fabric-wide
    rb = sb.submit("t", "batch", 1, now=0.0)
    sb._now = 120.0
    assert sb.effective_priority(rb) >= 1           # aged past reserve
    assert sb._reserve_for(rb) == 1                 # served on a: held
    # a tenant with no service anywhere still pierces after the bound
    rc = sb.submit("u", "batch", 1, now=0.0)
    sb._now = 120.0
    assert sb._reserve_for(rc) == 0


# -- live daemon --------------------------------------------------------------

def test_daemon_adaptive_feeds_estimator_and_exposes_history():
    """The daemon feeds the fabric estimator from the wall clock at
    submit and surfaces per-shell reserve_history."""
    spec = uniform_shell("host1_s1", (1, 1), 1)
    reg = default_registry()
    d = Daemon(Shell(spec), reg,
               PolicyConfig(reserve_mode="adaptive", reserve_slots_max=1))
    try:
        assert d.fabric.arrivals is not None
        img = np.random.default_rng(0).random((64, 64)).astype(np.float32)
        h1 = d.submit("live", "sobel", [(img,)], priority=3)
        h2 = d.submit("live", "sobel", [(img,)], priority=3)
        assert len(h1.future.result(timeout=300)) == 1
        assert len(h2.future.result(timeout=300)) == 1
        assert d.fabric.arrivals.interarrival_ms(3) is not None
        assert set(d.reserve_history) == {"host1_s1"}
    finally:
        d.shutdown()
