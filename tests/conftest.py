"""Tier-1 test setup.

Installs a minimal, deterministic in-repo fallback for `hypothesis` when
the real package is absent (the container image does not ship it), so the
property suites (`test_core_allocator.py`, `test_core_scheduler.py`,
`test_fault_and_bus.py`, `test_substrates.py`, `test_preemption.py`)
collect and run everywhere.

The shim supports exactly the API surface the suites use — `given`,
`settings(max_examples=, deadline=)`, and the strategies `integers`,
`floats`, `booleans`, `sampled_from`, `lists`, `tuples` — driven by a
`random.Random` seeded from the test name, so every run draws the same
examples.  No shrinking: a failing example's arguments appear verbatim in
the assertion traceback.
"""
from __future__ import annotations

import random
import sys
import types
import zlib


class _Strategy:
    """A strategy is just a draw function over a seeded RNG."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)

    # combinators used via st.lists(st.tuples(...)) nesting
    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def _lists(elements, min_size=0, max_size=None, **_):
    def draw(rng):
        hi = max_size if max_size is not None else min_size + 10
        n = rng.randint(min_size, hi)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def _tuples(*strats):
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strats))


def _just(value):
    return _Strategy(lambda rng: value)


def _none():
    return _Strategy(lambda rng: None)


def _one_of(*strats):
    return _Strategy(lambda rng: strats[rng.randrange(len(strats))]
                     .example(rng))


def _settings(max_examples: int = 100, deadline=None, **_):
    def deco(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn
    return deco


def _given(*strats):
    def deco(fn):
        def wrapper():
            cfg = getattr(wrapper, "_shim_settings", None) or \
                getattr(fn, "_shim_settings", {})
            n = cfg.get("max_examples", 50)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                vals = tuple(s.example(rng) for s in strats)
                try:
                    fn(*vals)
                except Exception:
                    print(f"falsifying example ({fn.__name__}): {vals!r}",
                          file=sys.stderr)
                    raise
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def _install_hypothesis_shim() -> None:
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name, obj in (("integers", _integers), ("floats", _floats),
                      ("booleans", _booleans),
                      ("sampled_from", _sampled_from), ("lists", _lists),
                      ("tuples", _tuples), ("just", _just),
                      ("none", _none), ("one_of", _one_of)):
        setattr(strat, name, obj)
    hyp.given = _given
    hyp.settings = _settings
    hyp.strategies = strat
    hyp.__version__ = "0.0-shim"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat


try:
    import hypothesis  # noqa: F401  (real package wins when available)
except ImportError:
    _install_hypothesis_shim()
